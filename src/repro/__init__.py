"""ImmunoBalance: immune-system load balancing for MIMD-scale JAX systems.

Reproduction + extension of Clark, "Immunological Approaches to Load Balancing in
MIMD Systems" (CS.DC 2022). See DESIGN.md.
"""
__version__ = "1.0.0"
