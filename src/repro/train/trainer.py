"""The training loop: checkpoint/restart, failure injection, immune scheduling.

Fault-tolerance contract (exercised by tests/test_train.py and tests/test_system.py):
  * auto-resume: on start, the trainer restores the newest valid checkpoint and
    continues from its step — a killed run resumes bitwise-identically (the data
    pipeline is a pure function of the step counter)
  * crash-safety: checkpoints are atomic (see dist/checkpoint.py); a failure mid-save
    falls back to the previous step
  * failure injection: ``failure_at`` raises mid-run to simulate a node loss
  * the immune scheduler tracks per-worker throughput and is checkpointed next to
    the train state, so anergy verdicts (who is presumed dead) and shard fractions
    survive a restart — a restored run resumes the paper's
    anergy -> checkpoint-restore -> revival loop instead of re-learning the fleet.
    ``heartbeats`` injects the fleet's per-worker throughput (tests simulate node
    loss with it); on a single host it defaults to the measured local step rate.
"""
from __future__ import annotations

import logging
import os
import time
from dataclasses import dataclass, field
from functools import lru_cache, partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..core import router as irouter
from ..core import scheduler as ischeduler
from ..data import pipeline
from ..dist import checkpoint as ckpt
from . import train_step as ts

Array = jax.Array

log = logging.getLogger(__name__)

_SCHED_SUBDIR = "sched"


@lru_cache(maxsize=32)
def _jit_step(cfg: ModelConfig, tcfg: TrainConfig, rcfg: irouter.RouterConfig):
    """Process-wide cache: every Trainer with the same (cfg, tcfg, rcfg) shares
    one compiled step — a resumed run re-executes the *identical* executable
    (bitwise-reproducible resume) and repeated small fixtures don't recompile."""
    return jax.jit(partial(ts.train_step, cfg=cfg, tcfg=tcfg, rcfg=rcfg),
                   donate_argnums=0)


@lru_cache(maxsize=32)
def _jit_data(cfg: ModelConfig, batch: int, seq: int):
    return jax.jit(partial(pipeline.sample_batch, cfg, batch, seq))


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    workdir: str
    batch: int = 8
    seq: int = 64
    ckpt_every: int = 50
    log_every: int = 10
    keep: Optional[int] = None             # checkpoint retention (None = keep all)
    rcfg: irouter.RouterConfig = field(default_factory=irouter.RouterConfig)
    scfg: ischeduler.SchedulerConfig = field(
        default_factory=ischeduler.SchedulerConfig)
    failure_at: Optional[int] = None       # simulate a node loss at this step
    num_workers: Optional[int] = None      # fleet size (default: process_count)
    # (step, local_throughput) -> (num_workers,) observed per-worker throughput;
    # 0 entries are missed heartbeats (anergy candidates)
    heartbeats: Optional[Callable[[int, float], np.ndarray]] = None
    on_metrics: Optional[Callable] = None

    def __post_init__(self):
        self._step_fn = _jit_step(self.cfg, self.tcfg, self.rcfg)
        self._data_fn = _jit_data(self.cfg, self.batch, self.seq)
        if self.num_workers is None:
            self.num_workers = jax.process_count()
        self.scheduler = ischeduler.init_scheduler(num_workers=self.num_workers)
        self.history: list[dict] = []

    def init_or_restore(self) -> tuple[ts.TrainState, int]:
        """Newest valid checkpoint (with its scheduler state), else a fresh init.

        Returns ``(state, step)`` with the step threaded explicitly: resume
        continues from the checkpoint's step label, which must agree with the
        ``state.step`` leaf it stored (the bitwise-resume tests pin this).
        """
        key = jax.random.PRNGKey(self.tcfg.seed)
        state = ts.init_train_state(key, self.cfg, self.tcfg)
        restored, step = ckpt.restore(self.workdir, state)
        if restored is None:
            return state, 0
        if int(restored.step) != step:
            # dir label and state leaf disagree (external tooling?): the leaf is
            # what the training math uses, so trust it — never abort auto-resume
            log.warning("checkpoint dir says step %d but state.step is %d; "
                        "resuming from the state leaf", step, int(restored.step))
            step = int(restored.step)
        # the sched restore prefers the snapshot matching the train state's
        # step (if the newest train checkpoint was corrupt and we fell back,
        # so does the sched restore); failing that, the newest sched snapshot
        # not newer than the train state — stale anergy memory beats amnesia
        for s in [step] + [x for x in reversed(ckpt.all_steps(self._sched_dir()))
                           if x < step]:
            sched, _ = ckpt.restore(self._sched_dir(), self.scheduler, step=s)
            if sched is not None:
                self.scheduler = sched
                break
        return restored, step

    def _sched_dir(self) -> str:
        return os.path.join(self.workdir, _SCHED_SUBDIR)

    def _checkpoint(self, state: ts.TrainState, step: int) -> None:
        ckpt.save(self.workdir, state, step, keep=self.keep)
        ckpt.save(self._sched_dir(), self.scheduler, step, keep=self.keep)

    def worker_fracs(self) -> np.ndarray:
        """Current per-worker shard fractions (drives per-host microbatch sizing)."""
        return np.asarray(self.scheduler.frac)

    def train(self, num_steps: int) -> ts.TrainState:
        state, start = self.init_or_restore()

        data_state = pipeline.DataState(step=jnp.asarray(start, jnp.int32))

        for step in range(start, num_steps):
            if self.failure_at is not None and step == self.failure_at:
                raise RuntimeError(f"injected node failure at step {step}")
            t0 = time.perf_counter()
            batch, data_state = self._data_fn(data_state)
            state, metrics = self._step_fn(state, batch)
            dt = time.perf_counter() - t0
            local_tput = 1.0 / max(dt, 1e-9)
            hb = (self.heartbeats(step, local_tput) if self.heartbeats is not None
                  else np.full((self.num_workers,), local_tput, np.float32))
            self.scheduler = ischeduler.observe(self.scheduler, jnp.asarray(hb),
                                                self.scfg)

            if step % self.log_every == 0 or step == num_steps - 1:
                rec = {"step": step, "loss": float(metrics.loss),
                       "grad_norm": float(metrics.grad_norm),
                       "lr": float(metrics.lr),
                       "load_cv": float(metrics.load_cv),
                       "drop_frac": float(metrics.drop_frac),
                       "anergic_workers": int(np.sum(np.asarray(
                           self.scheduler.anergic))),
                       "sec_per_step": dt}
                self.history.append(rec)
                if self.on_metrics:
                    self.on_metrics(rec)
            if (step + 1) % self.ckpt_every == 0 or step == num_steps - 1:
                self._checkpoint(state, step + 1)
        return state
