"""The training loop: checkpoint/restart, failure injection, immune scheduling.

Fault-tolerance contract (exercised by tests/test_trainer.py):
  * auto-resume: on start, the trainer restores the newest valid checkpoint and
    continues from its step — a killed run resumes bitwise-identically (the data
    pipeline is a pure function of the step counter)
  * crash-safety: checkpoints are atomic (see dist/checkpoint.py); a failure mid-save
    falls back to the previous step
  * failure injection: ``failure_at`` raises mid-run to simulate a node loss
  * the immune scheduler tracks per-worker throughput; on a real fleet its fractions
    drive per-host microbatch sizing (here it is fed measured host step times)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, TrainConfig
from ..core import router as irouter
from ..core import scheduler as ischeduler
from ..data import pipeline
from ..dist import checkpoint as ckpt
from . import train_step as ts

Array = jax.Array


@dataclass
class Trainer:
    cfg: ModelConfig
    tcfg: TrainConfig
    workdir: str
    batch: int = 8
    seq: int = 64
    ckpt_every: int = 50
    log_every: int = 10
    rcfg: irouter.RouterConfig = field(default_factory=irouter.RouterConfig)
    failure_at: Optional[int] = None       # simulate a node loss at this step
    on_metrics: Optional[Callable] = None

    def __post_init__(self):
        self._step_fn = jax.jit(partial(ts.train_step, cfg=self.cfg, tcfg=self.tcfg,
                                        rcfg=self.rcfg), donate_argnums=0)
        self._data_fn = jax.jit(partial(pipeline.sample_batch, self.cfg, self.batch,
                                        self.seq))
        self.scheduler = ischeduler.init_scheduler(num_workers=jax.process_count())
        self.history: list[dict] = []

    def init_or_restore(self) -> ts.TrainState:
        key = jax.random.PRNGKey(self.tcfg.seed)
        state = ts.init_train_state(key, self.cfg, self.tcfg)
        restored, step = ckpt.restore(self.workdir, state)
        if restored is not None:
            return restored
        return state

    def train(self, num_steps: int) -> ts.TrainState:
        state = self.init_or_restore()
        start = int(state.step)
        data_state = pipeline.DataState(step=jnp.asarray(start, jnp.int32))

        for step in range(start, num_steps):
            if self.failure_at is not None and step == self.failure_at:
                raise RuntimeError(f"injected node failure at step {step}")
            t0 = time.perf_counter()
            batch, data_state = self._data_fn(data_state)
            state, metrics = self._step_fn(state, batch)
            dt = time.perf_counter() - t0
            self.scheduler = ischeduler.observe(
                self.scheduler, jnp.asarray([1.0 / max(dt, 1e-9)]))

            if step % self.log_every == 0 or step == num_steps - 1:
                rec = {"step": step, "loss": float(metrics.loss),
                       "grad_norm": float(metrics.grad_norm),
                       "lr": float(metrics.lr),
                       "load_cv": float(metrics.load_cv),
                       "drop_frac": float(metrics.drop_frac),
                       "sec_per_step": dt}
                self.history.append(rec)
                if self.on_metrics:
                    self.on_metrics(rec)
            if (step + 1) % self.ckpt_every == 0 or step == num_steps - 1:
                ckpt.save(self.workdir, state, step + 1)
        return state
