from . import optimizer, train_step, trainer  # noqa: F401
