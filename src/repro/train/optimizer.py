"""AdamW + LR schedules in pure JAX (no optax), built for 1T-parameter sharding.

Memory knobs (the difference between fitting and not fitting kimi-k2 on v5e-16GB —
see EXPERIMENTS.md §Perf):
  * ``state_dtype``    — dtype of the first/second moments (fp32 default, bf16 option)
  * ``factored``       — Adafactor-style factored second moment for >=2D params
                         (row/col accumulators instead of a full v tensor)

Schedules: ``cosine`` and ``wsd`` (warmup-stable-decay, MiniCPM's schedule: linear
warmup, long stable plateau, then a short 1-sqrt decay tail).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------
def schedule(cfg: TrainConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "wsd":
        stable_end = cfg.stable_frac * cfg.decay_steps
        frac = jnp.clip((s - stable_end) / jnp.maximum(cfg.decay_steps - stable_end, 1),
                        0.0, 1.0)
        decay = 1.0 - jnp.sqrt(frac)          # MiniCPM's 1-sqrt tail
    else:
        frac = jnp.clip(s / jnp.maximum(cfg.decay_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * decay


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
class AdamWState(NamedTuple):
    step: Array
    mu: dict
    nu: dict       # full second moment, or {"row": ..., "col": ...} when factored


def _factorable(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] > 1 and x.shape[-2] > 1


def init_opt_state(params, state_dtype=jnp.float32, factored: bool = False):
    def mk_mu(p):
        return jnp.zeros(p.shape, state_dtype)

    def mk_nu(p):
        if factored and _factorable(p):
            return {"row": jnp.zeros(p.shape[:-1], state_dtype),
                    "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], state_dtype)}
        return jnp.zeros(p.shape, state_dtype)

    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(mk_mu, params),
                      nu=jax.tree.map(mk_nu, params))


def _nu_update(nu, g2, b2):
    if isinstance(nu, dict):
        row = b2 * nu["row"].astype(jnp.float32) + (1 - b2) * jnp.mean(g2, axis=-1)
        col = b2 * nu["col"].astype(jnp.float32) + (1 - b2) * jnp.mean(g2, axis=-2)
        return {"row": row, "col": col}
    return b2 * nu.astype(jnp.float32) + (1 - b2) * g2


def _nu_value(nu):
    if isinstance(nu, dict):
        row, col = nu["row"], nu["col"]
        denom = jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), 1e-30)
        return row[..., None] * col[..., None, :] / denom[..., None]
    return nu


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads, state: AdamWState, params, cfg: TrainConfig,
                 lr: Optional[Array] = None):
    """Returns (new_params, new_state, grad_norm). Weight decay is decoupled and
    skipped for 1-D params (norms, biases)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step) if lr is None else lr
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    is_nu_leaf = lambda x: isinstance(x, dict) and "row" in x

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = _nu_update(nu, jnp.square(gf), b2)
        mu_hat = mu_n / c1
        nu_hat = _nu_value(nu_n) / c2
        upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        to_state = lambda v: jax.tree.map(lambda x: x.astype(mu.dtype), v)
        return new_p, to_state(mu_n), to_state(nu_n)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu), gnorm
