"""The jit-compiled training step: loss/grad, microbatch accumulation, optimizer,
and the immune router regulation (state update outside the gradient path).

``TrainState`` is one pytree — shardable, donate-able, checkpoint-able.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, TrainConfig
from ..core import router as irouter
from ..models import model
from . import optimizer as opt

Array = jax.Array


class TrainState(NamedTuple):
    params: dict
    opt: opt.AdamWState
    router: Optional[irouter.RouterState]   # leaves (L, E); None for non-MoE
    step: Array


def init_router(cfg: ModelConfig) -> Optional[irouter.RouterState]:
    if not cfg.num_experts:
        return None
    one = irouter.init_router_state(cfg.num_experts)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape).copy(), one)


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig,
                     state_dtype=jnp.float32, factored: bool = False) -> TrainState:
    params = model.init_params(key, cfg)
    return TrainState(
        params=params,
        opt=opt.init_opt_state(params, state_dtype=state_dtype, factored=factored),
        router=init_router(cfg),
        step=jnp.zeros((), jnp.int32),
    )


class Metrics(NamedTuple):
    loss: Array
    grad_norm: Array
    lr: Array
    aux_loss: Array
    drop_frac: Array
    load_cv: Array       # mean over layers of expert-load CV (0 for dense)


def train_step(state: TrainState, batch: dict, cfg: ModelConfig, tcfg: TrainConfig,
               rcfg: irouter.RouterConfig = irouter.RouterConfig()):
    """One optimizer step (with tcfg.accum_steps microbatches via lax.scan)."""
    bias = state.router.bias if state.router is not None else None

    def loss_fn(params, mb):
        out = model.train_loss(params, cfg, mb, router_bias=bias)
        return out.loss, out

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    if tcfg.accum_steps > 1:
        def split(x):
            return x.reshape((tcfg.accum_steps, x.shape[0] // tcfg.accum_steps)
                             + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_body(carry, mb):
            g_acc, l_acc, out_acc = carry
            (loss, out), g = grad_fn(state.params, mb)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            out_acc = jax.tree.map(jnp.add, out_acc, _stats(out, cfg))
            return (g_acc, l_acc + loss, out_acc), None

        zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               state.params)
        zeros_o = jax.tree.map(jnp.zeros_like, _stats_spec(cfg))
        (grads, loss_sum, stats_sum), _ = jax.lax.scan(
            acc_body, (zeros_g, jnp.zeros(()), zeros_o), micro)
        inv = 1.0 / tcfg.accum_steps
        grads = jax.tree.map(lambda g: g * inv, grads)
        loss = loss_sum * inv
        stats = jax.tree.map(lambda s: s * inv, stats_sum)
    else:
        (loss, out), grads = grad_fn(state.params, batch)
        stats = _stats(out, cfg)

    new_params, new_opt, gnorm = opt.adamw_update(grads, state.opt, state.params, tcfg)

    new_router = state.router
    load_cv = jnp.zeros(())
    if state.router is not None:
        load = stats["load_frac"]                       # (L, E)
        upd = jax.vmap(lambda st, l: irouter.update_router_state(st, l, rcfg))
        new_router = upd(state.router, load)
        load_cv = jnp.mean(jax.vmap(irouter.load_cv)(load))

    metrics = Metrics(loss=loss, grad_norm=gnorm,
                      lr=opt.schedule(tcfg, state.step + 1),
                      aux_loss=stats["aux"], drop_frac=stats["drop"],
                      load_cv=load_cv)
    new_state = TrainState(params=new_params, opt=new_opt, router=new_router,
                           step=state.step + 1)
    return new_state, metrics


def _stats(out: model.TrainOut, cfg: ModelConfig) -> dict:
    return {
        "load_frac": (out.load_frac if out.load_frac is not None
                      else jnp.zeros((1, 1))),
        "aux": out.aux_loss,
        "drop": out.drop_frac,
    }


def _stats_spec(cfg: ModelConfig) -> dict:
    e = max(cfg.num_experts, 1)
    l = cfg.num_layers if cfg.num_experts else 1
    return {"load_frac": jnp.zeros((l, e)), "aux": jnp.zeros(()),
            "drop": jnp.zeros(())}
