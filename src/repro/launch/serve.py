"""Serving entry point: batched prefill + decode on the host's devices, optionally
restoring trained parameters from a checkpoint directory.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --batch 4 --steps 32 [--restore /tmp/run1]

``--stream`` switches from the one-shot fixed batch to the continuous-batching
engine driven by a synthetic open-loop arrival trace (bursty, heterogeneous
request classes — or ``--trace shared-prefix`` for system-prompt traffic that
exercises refcounted prefix page sharing), with admission governed by the
immune primitives. The engine is driven through ``Engine.stream()``: per-token
``RequestOutput`` deltas print as they are emitted (first ``--show-stream``
request ids), and ``--temperature/--top-p/--top-k/--sample-seed`` give every
request a seeded sampling lane instead of greedy:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --stream --requests 40 --slots 4 [--policy fifo] \
        [--temperature 0.8 --top-p 0.9 --sample-seed 7] \
        [--trace shared-prefix|returning-tenant|contention|fleet] \
        [--no-prefix-sharing] [--pin-pages 8] [--admission reserve] \
        [--logprobs] [--attn-backend pallas_interpret] [--prefill-streams 2]

``--replicas N`` (with ``--stream``) serves the trace through the
multi-replica placement router instead of one engine: N identical replicas,
one global queue, per-tick placement under ``--router immune|rr|jsq`` —
immune placement routes by prefix affinity, drains anergic replicas, and
prices backlog at remembered per-class cost (see serve/router.py):

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --stream --trace fleet --replicas 3 --router immune --pin-pages 8

``--faults "crash@8:r1 rejoin@24:r1"`` (with ``--replicas > 1``) scripts
seeded, tick-exact replica faults into the run (``serve.faults`` grammar:
crash / slow / stall / page-pressure / cold rejoin) and exercises the
router's missed-deadline health machine — suspect fencing, bitwise-exact
evacuation + re-placement on survivors, retry budget, rejoin rewarming.
``--trace fleet-faults`` serves the fleet trace with a crash+rejoin plan
auto-sized to the arrival window when ``--faults`` is not given:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --stream --trace fleet-faults --replicas 3 --router immune \
        [--faults "crash@7:r1 rejoin@17:r1"]

``--journal PATH`` arms the write-ahead request journal (and, with
``--snapshot-dir``/``--snapshot-every``, warm snapshots of the pinned cache
+ immune memories) on the router. A fault plan containing ``poweroff@tick``
— or ``--trace fleet-poweroff``, which auto-sizes one to the arrival window
— switches the drive to ``serve.durability.run_durable``: the whole fleet
fail-stops mid-trace, the journal is truncated to its fsync'd prefix, and a
fresh fleet recovers and finishes the trace with bitwise-identical streams:

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
        --stream --trace fleet-poweroff --replicas 2 --router immune \
        --journal /tmp/serve.wal --snapshot-dir /tmp/serve-snap \
        --snapshot-every 4 [--sync-every 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import checkpoint as ckpt
from repro.launch.mesh import make_host_mesh
from repro.models import layers as layers_mod
from repro.models import model as model_lib
from repro.serve import decode as serve_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--restore", default=None,
                    help="checkpoint dir from repro.launch.train")
    ap.add_argument("--stream", action="store_true",
                    help="continuous-batching engine on a synthetic open-loop "
                         "arrival trace instead of a one-shot fixed batch")
    ap.add_argument("--policy", default="immune", choices=("immune", "fifo"))
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--latency-budget", type=float, default=24.0)
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV page size (tokens per physical page)")
    ap.add_argument("--pages", type=int, default=None,
                    help="page-pool size incl. the null page; default fully "
                         "provisioned (slots x max_cache worth)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked prefill size; 0 = one-shot prefill")
    ap.add_argument("--prefill-streams", type=int, default=1,
                    help=">1: batch that many concurrent prefill jobs into "
                         "one compiled call per tick (attention stacks)")
    ap.add_argument("--prefix-sharing", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="refcounted prompt-prefix page sharing (CoW forks); "
                         "--no-prefix-sharing for the single-owner allocator")
    ap.add_argument("--attn-backend", default="xla",
                    choices=("xla", "pallas", "pallas_interpret"),
                    help="paged decode attention: XLA gather fallback, or the "
                         "kernels.paged_attention Pallas kernel (pallas = "
                         "compiled on TPU, pallas_interpret = runs anywhere)")
    ap.add_argument("--trace", default="bursty",
                    choices=("bursty", "shared-prefix", "returning-tenant",
                             "contention", "agentic", "fleet", "fleet-faults",
                             "fleet-poweroff"),
                    help="synthetic arrival trace: bursty heterogeneous, "
                         "system-prompt traffic (exercises prefix sharing), "
                         "returning-tenant bursts with drain gaps (exercises "
                         "the pinned prefix cache), page-pool contention "
                         "(exercises preemptive admission), agentic "
                         "multi-turn re-submission with grown prompt "
                         "prefixes (exercises prefix sharing + speculative "
                         "decoding), multi-tenant fleet traffic with "
                         "hot-replica skew (exercises the placement router), "
                         "the fleet trace fault-laced with an auto-sized "
                         "crash+rejoin plan (exercises failover; needs "
                         "--replicas > 1), or the fleet trace with an "
                         "auto-sized full-fleet poweroff + restart "
                         "(exercises journal + snapshot recovery)")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1: serve through the multi-replica placement "
                         "router (serve.router) — N engine replicas, one "
                         "global queue, per-tick placement")
    ap.add_argument("--faults", default=None, metavar="PLAN",
                    help="script seeded tick-exact replica faults into a "
                         "--replicas > 1 run, e.g. 'crash@8:r1 rejoin@24:r1 "
                         "slow@4+10:r0:x3' (serve.faults plan grammar); the "
                         "router detects and fails over, the injector never "
                         "announces")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="write-ahead request journal file (serve.durability):"
                         " every accepted request, emitted token and terminal "
                         "outcome is logged, fsync'd per --sync-every ticks; "
                         "required (auto-defaulted for --trace fleet-poweroff)"
                         " when the fault plan contains poweroff@tick")
    ap.add_argument("--snapshot-dir", default=None,
                    help="warm-snapshot directory: pinned prefix cache (with "
                         "K/V), immune memories and router books, written "
                         "atomically every --snapshot-every ticks")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="warm-snapshot cadence in fleet ticks (0 = off)")
    ap.add_argument("--sync-every", type=int, default=1,
                    help="journal group-commit cadence: one fsync per this "
                         "many ticks (submits always fsync immediately)")
    ap.add_argument("--router", default="immune",
                    choices=("immune", "rr", "jsq"),
                    help="placement policy over the replicas: immune "
                         "(prefix affinity -> anergy draining -> least "
                         "remembered cost), round-robin, or "
                         "join-shortest-queue")
    ap.add_argument("--pin-pages", type=int, default=0,
                    help="pinned prefix-cache budget in pages: refcount-zero "
                         "indexed pages survive up to this many, evicted by "
                         "immune-memory-weighted LRU (0 = legacy free-on-zero)")
    ap.add_argument("--admission", default="preempt",
                    choices=("preempt", "reserve"),
                    help="page admission discipline: admit on current pages "
                         "and preempt the lowest-priority slot on decode "
                         "exhaustion, or legacy worst-case reservation")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="self-speculative decoding: draft K tokens per tick "
                         "through the first --spec-draft-layers layer reps "
                         "and verify them in one batched paged step; greedy "
                         "accept is bitwise-identical to non-speculative "
                         "decode (0 = off; sampled/logprobs ticks fall back)")
    ap.add_argument("--spec-draft-layers", type=int, default=0,
                    help="truncated draft depth in layer repetitions "
                         "(required > 0 and < num_layers with --spec-decode)")
    ap.add_argument("--logprobs", action="store_true",
                    help="record each chosen token's logprob (raw model "
                         "distribution) in the streamed outputs")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="per-request sampling temperature; 0 = exact greedy")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 disables)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k logits filter (0 disables)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base sampling seed; request rid offsets it, so a "
                         "trace replays token-identically")
    ap.add_argument("--show-stream", type=int, default=4,
                    help="print per-token stream deltas for this many "
                         "request ids (0 silences the stream)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    layers_mod.set_mesh_axes(mesh)

    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)
    bias = (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)
    if args.restore:
        from repro.configs.base import TrainConfig
        from repro.train import train_step as ts
        like = ts.init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
        state, step = ckpt.restore(args.restore, like)
        assert state is not None, f"no checkpoint in {args.restore}"
        params = state.params
        if state.router is not None:
            bias = state.router.bias
        print(f"restored step {step} from {args.restore}")

    if args.stream:
        import math

        from repro.serve import engine as eng_mod
        from repro.serve import traces
        lcm = math.lcm(args.page_size, args.prefill_chunk or 1)
        raw = args.prompt_len + args.steps + 48
        ecfg = eng_mod.EngineConfig(
            num_slots=args.slots,
            max_cache=-(-raw // lcm) * lcm,     # round up to page/chunk grain
            policy=args.policy, num_classes=3,
            latency_budget=args.latency_budget,
            page_size=args.page_size, num_pages=args.pages,
            prefill_chunk=args.prefill_chunk,
            prefix_sharing=args.prefix_sharing,
            attn_backend=args.attn_backend,
            prefill_streams=args.prefill_streams,
            pin_pages=args.pin_pages,
            admission_mode=args.admission,
            spec_decode=args.spec_decode,
            spec_draft_layers=args.spec_draft_layers)
        sampling = dict(temperature=args.temperature, top_p=args.top_p,
                        top_k=args.top_k, sample_seed=args.sample_seed)
        if args.trace == "shared-prefix":
            trace = traces.shared_prefix_trace(
                cfg, num_requests=args.requests,
                prefix_len=max(args.prompt_len, 2 * args.page_size),
                decode_lens=(args.steps // 2, args.steps), **sampling)
        elif args.trace == "returning-tenant":
            trace = traces.returning_tenant_trace(
                cfg, prefix_len=max(args.prompt_len, 2 * args.page_size),
                bursts=max(2, args.requests // 12),
                decode_lens=(args.steps // 2,), **sampling)
        elif args.trace == "contention":
            trace = traces.contention_trace(
                cfg, num_requests=args.requests,
                hog_prompt=2 * args.page_size,
                hog_tokens=args.steps, **sampling)
        elif args.trace == "agentic":
            trace = traces.agentic_trace(
                cfg, sessions=max(1, args.requests // 4), turns=4,
                base_prompt=max(args.prompt_len, 2 * args.page_size),
                decode_lens=(args.steps // 2, args.steps), **sampling)
        elif args.trace in ("fleet", "fleet-faults", "fleet-poweroff"):
            fleet_kw = dict(
                num_requests=args.requests,
                prefix_len=max(args.prompt_len, 2 * args.page_size),
                decode_lens=(args.steps // 2, args.steps), **sampling)
            if args.trace == "fleet-faults":
                if args.replicas < 2:
                    ap.error("--trace fleet-faults needs --replicas > 1 "
                             "(faults target replicas behind the router)")
                trace, auto_spec = traces.failover_fleet_trace(
                    cfg, replicas=args.replicas,
                    crash_replica=args.replicas - 1, **fleet_kw)
                args.faults = args.faults or auto_spec
            elif args.trace == "fleet-poweroff":
                if args.replicas < 2:
                    ap.error("--trace fleet-poweroff needs --replicas > 1 "
                             "(the poweroff fault fires through the router's "
                             "fault injector)")
                trace, auto_spec = traces.poweroff_fleet_trace(cfg, **fleet_kw)
                args.faults = args.faults or auto_spec
            else:
                trace = traces.fleet_trace(cfg, **fleet_kw)
        else:
            trace = traces.synthetic_trace(cfg, num_requests=args.requests,
                                           heavy_tokens=args.steps + 8,
                                           **sampling)
        if args.logprobs:
            from dataclasses import replace as _dc_replace
            for req in trace:
                req.params = _dc_replace(req.params, logprobs=True)
        if args.faults and args.replicas < 2:
            ap.error("--faults needs --stream --replicas > 1 (faults target "
                     "replicas behind the router)")
        if args.replicas > 1:
            from repro.serve import router as rt_mod
            poweroff_plan = bool(args.faults) and "poweroff" in args.faults
            if poweroff_plan and not args.journal:
                import os
                import tempfile
                args.journal = os.path.join(
                    tempfile.mkdtemp(prefix="serve_wal_"), "journal.wal")
                print(f"poweroff plan with no --journal: journaling to "
                      f"{args.journal}")

            def make_router():
                injector = None
                if args.faults:
                    from repro.serve.faults import FaultInjector, FaultPlan
                    injector = FaultInjector(
                        FaultPlan.parse(args.faults),
                        engine_factory=lambda: eng_mod.Engine(
                            params, cfg, ecfg, router_bias=bias))
                fleet = [eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
                         for _ in range(args.replicas)]
                return rt_mod.Router(fleet,
                                     rt_mod.RouterConfig(policy=args.router),
                                     injector=injector)

            if args.faults:
                print(f"fault plan: {args.faults}")
            with mesh:
                t0 = time.perf_counter()
                if poweroff_plan:
                    from repro.serve import durability
                    router, stats = durability.run_durable(
                        make_router, trace, args.journal,
                        snapshot_dir=args.snapshot_dir,
                        snapshot_every=args.snapshot_every,
                        sync_every=args.sync_every,
                        max_ticks=50 * args.requests)
                else:
                    router = make_router()
                    if args.journal:
                        from repro.serve import durability
                        router.attach_durability(
                            durability.RequestJournal(
                                args.journal, sync_every=args.sync_every),
                            snapshot_dir=args.snapshot_dir,
                            snapshot_every=args.snapshot_every)
                    stats = router.run(trace, max_ticks=50 * args.requests)
                    if router.journal is not None:
                        router.journal.close()
            dt = time.perf_counter() - t0
            print(f"[{args.router} x {args.replicas}] {stats['completed']} "
                  f"completed / {stats['shed']} shed / {stats['rejected']} "
                  f"rejected of {args.requests} requests in {stats['ticks']} "
                  f"ticks ({dt:.1f}s wall incl. compile)")
            print(f"  throughput {stats['throughput']:.2f} tok/tick | p50 "
                  f"{stats['p50_latency']:.0f} / p99 {stats['p99_latency']:.0f}"
                  f" ticks | goodput {stats['goodput']:.2f}")
            print(f"  placements {stats['placements']} (imbalance "
                  f"{stats['placement_imbalance']:.2f}) | affinity "
                  f"{stats['affinity_hits']}/{stats['affinity_checks']} hits "
                  f"({stats['affinity_tokens']} resident tokens) | "
                  f"{stats['drain_skips']} drain skips / "
                  f"{stats['drain_overflow']} overflow")
            print(f"  fleet: {stats['prefill_tokens']} prefill tokens | "
                  f"{stats['preemptions']} preemptions | "
                  f"{stats['replayed_tokens']} tokens replayed | "
                  f"{stats['pinned_pages_adopted']} pinned pages adopted")
            for i, p in enumerate(stats["per_replica"]):
                print(f"  replica {i}: {p['completed']} completed | "
                      f"p99 {p['p99_latency']:.0f} ticks | pages hw "
                      f"{p['pages_hw']}/{p['pages_budget']} | pinned-hit rate "
                      f"{p['pinned_hit_rate']:.2f}")
            if args.faults:
                print(f"  failover: {stats['deaths']} deaths / "
                      f"{stats['rejoins']} rejoins, "
                      f"{stats['replaced_requests']} re-placed "
                      f"({stats['retries']} retries, {stats['failed']} "
                      f"failed), recovery {stats['recovery_ticks']} ticks, "
                      f"health {stats['health']}")
            if args.journal:
                d = stats["durability"]
                j = d["journal"] or {}
                print(f"  durability: {stats.get('restarts', 0)} restarts | "
                      f"journal {j.get('records', 0)} records / "
                      f"{j.get('syncs', 0)} fsyncs "
                      f"(group commit {j.get('sync_every', 1)}) | "
                      f"recovered {d['recovered_finished']} finished + "
                      f"{d['recovered_open']} replayed | "
                      f"{d['recovered_pinned_pages']} pinned pages warm | "
                      f"{d['snapshots']} snapshots")
            return
        eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
        with mesh:
            t0 = time.perf_counter()
            # the streaming front door: RequestOutput deltas per tick, the
            # terminal one carrying the finish reason + latency accounting
            for out in eng.stream(trace, max_ticks=50 * args.requests):
                if out.rid >= args.show_stream or \
                        (not out.new_tokens and not out.finished):
                    continue
                tail = f" [{out.finish_reason}, {out.latency_ticks} ticks, " \
                       f"{out.wall_latency_s * 1e3:.0f} ms]" \
                    if out.finished and out.latency_ticks is not None \
                    else (f" [{out.finish_reason}]" if out.finished else "")
                print(f"  tick {out.tick:4d} req {out.rid} "
                      f"+= {out.new_tokens}{tail}")
            stats = eng.stats()
        dt = time.perf_counter() - t0
        print(f"[{args.policy}] {stats['completed']} completed / "
              f"{stats['shed']} shed / {stats['rejected']} rejected of "
              f"{args.requests} requests in "
              f"{stats['ticks']} ticks ({dt:.1f}s wall incl. compile)")
        print(f"  throughput {stats['throughput']:.2f} tok/tick | "
              f"p50 {stats['p50_latency']:.0f} / p99 {stats['p99_latency']:.0f} "
              f"ticks | p99 wall {stats['p99_wall_ms']:.0f} ms | "
              f"goodput {stats['goodput']:.2f} | "
              f"{stats['mid_stream_admissions']} mid-stream admissions")
        print(f"  sampling: {stats['sampled_requests']} sampled requests "
              f"(temperature {args.temperature}, top-p {args.top_p}, "
              f"top-k {args.top_k}, seed {args.sample_seed})")
        if args.spec_decode:
            print(f"  spec decode: k={stats['spec_decode']} | "
                  f"{stats['spec_ticks']} spec ticks | accept rate "
                  f"{stats['spec_accept_rate']:.2f} "
                  f"({stats['spec_accepted']}/{stats['spec_drafted']} drafts) "
                  f"| {stats['spec_emitted']} tokens emitted speculatively")
        print(f"  paged KV: {stats['pages_hw']}/{stats['pages_budget']} pages "
              f"high-water x {stats['page_size']} tokens | up to "
              f"{stats['concurrency_hw']} concurrent | "
              f"{stats['chunked_prefill_chunks']} prefill chunks landed in "
              f"{stats['prefill_batch_calls']} batched calls "
              f"[{stats['attn_backend']} decode]")
        print(f"  prefix sharing {'on' if stats['prefix_sharing'] else 'off'}:"
              f" hit rate {stats['prefix_hit_rate']:.2f} | "
              f"{stats['shared_pages_adopted']} pages adopted | "
              f"{stats['cow_forks']} CoW forks | "
              f"{stats['nowrite_adoptions']} no-write adoptions | "
              f"{stats['prefill_positions_skipped']} prefill positions "
              f"skipped")
        print(f"  memory hierarchy [{stats['admission_mode']}]: "
              f"pin budget {stats['pin_pages']} pages | "
              f"{stats['pages_pinned']} pinned at exit | {stats['pins']} pins "
              f"/ {stats['pin_evictions']} evictions | pinned-hit rate "
              f"{stats['pinned_hit_rate']:.2f} | {stats['preemptions']} "
              f"preemptions over {stats['preempted_requests']} requests | "
              f"{stats['replayed_tokens']} tokens replayed | "
              f"{stats['prefill_tokens']} prefill tokens computed")
        for r in eng.completed[:4]:
            print(f"  req {r.rid} (class {r.rclass}): arrived {r.arrival}, "
                  f"admitted {r.admit_tick}, finished {r.finish_tick}: "
                  f"{r.out_tokens[:12]}{'...' if len(r.out_tokens) > 12 else ''}")
        return

    key = jax.random.PRNGKey(1)
    prompts = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                            0, cfg.vocab_size)}
    if cfg.family == "vlm":
        prompts["patches"] = jax.random.normal(
            key, (args.batch, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        prompts["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.frontend_dim))

    with mesh:
        t0 = time.perf_counter()
        toks, _ = serve_mod.generate(
            params, cfg, prompts, max_cache=args.prompt_len + args.steps + 8,
            steps=args.steps, router_bias=bias)
        toks.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"{args.batch} x {args.steps} tokens in {dt:.1f}s (incl. compile); "
          f"{args.batch * args.steps / dt:.1f} tok/s")
    for i, row in enumerate(toks):
        print(f"  seq {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
