"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on 512
placeholder devices; emit memory / cost / collective roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all            # all 40 cells x 2 meshes

Per-cell results are appended as JSON lines to --out (default
benchmarks/results/dryrun.jsonl) — the roofline tables in EXPERIMENTS.md are built
from that file.
"""
# The VERY FIRST lines, before ANY other import — jax locks the device count on
# first init, and ONLY the dry-run may see 512 placeholder devices:
import os  # noqa: E402

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
from functools import partial  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import configs                    # noqa: E402
from repro.configs.base import ParallelConfig, TrainConfig  # noqa: E402
from repro.dist import sharding as shd       # noqa: E402
from repro.launch import roofline            # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model               # noqa: E402
from repro.train import train_step as ts     # noqa: E402

# TPU v5e hardware constants (per assignment)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
HBM_BYTES = 16 * 2 ** 30   # v5e HBM capacity

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=\n]*?"
    r"\s(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\([^\n]*")

# wire-traffic multiplier per op kind (ring algorithms, result-shape accounting)
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_WHILE_RE = re.compile(r"op_name=\"[^\"]*?/while/")


def collective_bytes(hlo_text: str, scan_trips: int = 1) -> tuple[float, dict]:
    """Per-device wire bytes, summed over collective ops in the partitioned HLO
    (shapes in an SPMD module are local/per-device).

    A collective that lives inside a ``lax.scan`` (while) body appears ONCE in the
    HLO text but executes once per layer — we detect loop membership from the op's
    jax-level op_name metadata (``.../while/body/...``) and multiply those ops by
    ``scan_trips`` (the depth of the layer scan). Without this the collective term
    is ~L x under-counted for scanned models."""
    total = 0.0
    by_kind: dict[str, float] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        trips = scan_trips if _WHILE_RE.search(m.group(0)) else 1
        eff = nbytes * _COLL_FACTOR[kind] * trips
        total += eff
        by_kind[kind] = by_kind.get(kind, 0.0) + eff
    return total, by_kind


def _flops_bytes(compiled) -> tuple[float, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = int(getattr(ma, k, 0))
    out["total_nonaliased"] = (out["argument_size_in_bytes"]
                               + out["output_size_in_bytes"]
                               + out["temp_size_in_bytes"]
                               - out["alias_size_in_bytes"])
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               pcfg: ParallelConfig = ParallelConfig(),
               opt_dtype: str = "float32", factored: bool = False):
    """Lower + compile one (arch, shape, mesh) cell; return the roofline record."""
    cfg = configs.get_config(arch)
    if pcfg.remat != "none":
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=pcfg.remat)
    if pcfg.capacity_factor is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, capacity_factor=pcfg.capacity_factor)
    shape = configs.get_shape(shape_name)
    okay, why = configs.cell_supported(cfg, shape)
    if not okay:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models import layers as _layers
    _layers.set_mesh_axes(mesh)
    if cfg.num_experts:
        import dataclasses
        dp = mesh.devices.size // mesh.shape["model"]
        cfg = dataclasses.replace(cfg, dispatch_groups=dp)
    tcfg = TrainConfig()
    batch_abs = configs.input_specs(cfg, shape)
    t0 = time.time()

    if shape.kind == "train":
        state_abs = jax.eval_shape(
            lambda: ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg,
                                        state_dtype=jnp.dtype(opt_dtype),
                                        factored=factored))
        state_shard = shd.train_state_shardings(state_abs, cfg, mesh, pcfg)
        batch_shard = shd.batch_shardings(batch_abs, mesh, pcfg)
        rep = NamedSharding(mesh, P())
        fn = partial(ts.train_step, cfg=cfg, tcfg=tcfg)
        with mesh:
            lowered = jax.jit(
                fn, in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, rep),
                donate_argnums=0,
            ).lower(state_abs, batch_abs)
            compiled = lowered.compile()
        tokens = shape.global_batch * shape.seq_len
    else:
        params_abs = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0), cfg))
        bias_abs = (jax.ShapeDtypeStruct((cfg.num_layers, cfg.num_experts),
                                         jnp.float32)
                    if cfg.num_experts else None)
        param_shard = shd.param_shardings(params_abs, cfg, mesh, pcfg)
        batch_shard = shd.batch_shardings(batch_abs, mesh, pcfg)
        rep = NamedSharding(mesh, P())
        bias_shard = rep if cfg.num_experts else None

        if shape.kind == "prefill":
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
            cache_shard = shd.cache_shardings(cache_abs, cfg, mesh, pcfg)

            def fn(params, batch, cache, bias):
                return model.prefill(params, cfg, batch, cache, router_bias=bias)

            with mesh:
                lowered = jax.jit(
                    fn,
                    in_shardings=(param_shard, batch_shard, cache_shard, bias_shard),
                    out_shardings=(rep, cache_shard),
                    donate_argnums=2,
                ).lower(params_abs, batch_abs, cache_abs, bias_abs)
                compiled = lowered.compile()
            tokens = shape.global_batch * shape.seq_len
        else:  # decode
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(cfg, shape.global_batch, shape.seq_len))
            cache_shard = shd.cache_shardings(cache_abs, cfg, mesh, pcfg)

            def fn(params, batch, cache, bias):
                return model.decode_step(params, cfg, batch, cache,
                                         router_bias=bias)

            with mesh:
                lowered = jax.jit(
                    fn,
                    in_shardings=(param_shard, batch_shard, cache_shard, bias_shard),
                    out_shardings=(rep, cache_shard),
                    donate_argnums=2,
                ).lower(params_abs, batch_abs, cache_abs, bias_abs)
                compiled = lowered.compile()
            tokens = shape.global_batch

    compile_s = time.time() - t0
    xla_flops_pd, xla_bytes_pd = _flops_bytes(compiled)
    from repro.models.transformer import segments as _segments
    scan_trips = max(reps for _, reps in _segments(cfg))
    coll_pd, coll_by_kind = collective_bytes(compiled.as_text(), scan_trips)
    mem = _memory(compiled)

    n_chips = mesh.devices.size
    params_abs2 = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0), cfg))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params_abs2))
    n_active = model.active_param_count(params_abs2, cfg)
    # standard accounting: 6·N_active·D for training (fwd 2ND + bwd 4ND),
    # 2·N_active·D for inference
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens

    terms = roofline.analytic_terms(cfg, shape, pcfg, n_params, n_active, n_chips,
                                    opt_dtype=opt_dtype, factored=factored,
                                    peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW)
    t_coll = coll_pd / LINK_BW
    dominant = max(("compute", terms.t_compute), ("memory", terms.t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    bound = max(terms.t_compute, terms.t_memory, t_coll)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "pcfg": {"fsdp": pcfg.fsdp, "seq_shard": pcfg.seq_shard,
                 "expert_parallel": pcfg.expert_parallel, "remat": pcfg.remat,
                 "capacity_factor": pcfg.capacity_factor,
                 "opt_dtype": opt_dtype, "factored": factored},
        "chips": int(n_chips),
        "params": n_params, "active_params": n_active, "tokens_per_step": tokens,
        # analytic roofline terms (see launch/roofline.py for the model)
        "flops_per_device": terms.flops_per_device,
        "hbm_bytes_per_device": terms.hbm_bytes_per_device,
        "state_bytes_per_device": terms.state_bytes_per_device,
        "collective_bytes_per_device": coll_pd, "collective_by_kind": coll_by_kind,
        "t_compute_s": terms.t_compute, "t_memory_s": terms.t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_total": model_flops,
        "useful_flops_frac": model_flops / max(terms.flops_total, 1.0),
        "roofline_frac": (model_flops / n_chips / PEAK_FLOPS) / max(bound, 1e-12),
        # XLA observables (CPU backend: while-body undercount / unfused upper bound —
        # recorded as secondary signals, see EXPERIMENTS.md §Methodology)
        "xla_flops_per_device": xla_flops_pd,
        "xla_bytes_per_device": xla_bytes_pd,
        "memory": mem,
        "fits_hbm": terms.state_bytes_per_device <= 0.9 * HBM_BYTES,
        "compile_s": compile_s,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-expert-parallel", action="store_true")
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--factored", action="store_true")
    args = ap.parse_args()

    pcfg = ParallelConfig(fsdp=not args.no_fsdp, seq_shard=args.seq_shard,
                          expert_parallel=not args.no_expert_parallel,
                          remat=args.remat, capacity_factor=args.capacity_factor)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)

    cells = []
    if args.all:
        for arch in sorted(configs.ARCHS):
            for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all) required"
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in cells:
        try:
            rec = lower_cell(arch, shape, mp, pcfg,
                             opt_dtype=args.opt_dtype, factored=args.factored)
        except Exception as e:  # a failing cell is a bug — record it loudly
            rec = {"arch": arch, "shape": shape,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"[:2000]}
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")
        if rec["status"] == "ok":
            print(f"{arch:24s} {shape:12s} {rec['mesh']:8s} OK "
                  f"compute={rec['t_compute_s']:.3e}s "
                  f"memory={rec['t_memory_s']:.3e}s "
                  f"coll={rec['t_collective_s']:.3e}s "
                  f"dom={rec['dominant']:10s} roof={rec['roofline_frac']:.2f} "
                  f"fits={rec['fits_hbm']} compile={rec['compile_s']:.0f}s",
                  flush=True)
        else:
            print(f"{arch:24s} {shape:12s} {rec['mesh']:8s} "
                  f"{rec['status'].upper()}: {rec.get('reason', rec.get('error'))}",
                  flush=True)


if __name__ == "__main__":
    main()
