"""Production mesh construction. A FUNCTION, not a module constant — importing this
module must never touch jax device state (smoke tests see 1 device; only the dry-run
forces 512 host devices)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has — used by examples and CPU tests."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
