"""Training entry point: real runs on whatever devices exist (CPU/TPU), with the
full substrate — sharded state, checkpointing/auto-resume, immune MoE balancing.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 100 --workdir /tmp/run1

``--smoke`` trains the reduced config (CPU-feasible); without it, the full assigned
config is instantiated — on real hardware only.
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.configs.base import TrainConfig
from repro.models import layers as layers_mod
from repro.launch.mesh import make_host_mesh
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--workdir", required=True)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    layers_mod.set_mesh_axes(mesh)

    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=max(args.steps // 20, 5),
                       decay_steps=args.steps, schedule=args.schedule)
    tr = Trainer(
        cfg=cfg, tcfg=tcfg, workdir=args.workdir, batch=args.batch, seq=args.seq,
        ckpt_every=args.ckpt_every,
        on_metrics=lambda m: print(
            f"step {m['step']:5d}  loss {m['loss']:.4f}  "
            f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}  "
            f"load_cv {m['load_cv']:.3f}  {m['sec_per_step']:.2f}s/step",
            flush=True))
    with mesh:
        tr.train(args.steps)
    print(f"done; checkpoints in {args.workdir}")


if __name__ == "__main__":
    main()
