"""Analytic roofline terms per (arch, shape, parallelism) cell.

Why analytic: this container compiles for the *CPU* backend, whose cost/memory
analyses diverge from TPU reality in two known ways (documented in EXPERIMENTS.md):
XLA's cost analysis under-counts while-loop (scan) bodies, and 'bytes accessed' is an
unfused upper bound. So the compute/memory roofline terms are derived analytically
from the model math (the same accounting MaxText-style MFU uses), while the
*collective* term comes from the partitioned HLO (op shapes there are real). The XLA
numbers are still recorded as secondary observables.

All returned byte/flop counts are PER DEVICE unless suffixed _total.
"""
from __future__ import annotations

from dataclasses import dataclass

from ..configs.base import ModelConfig, ParallelConfig, ShapeConfig
from ..models.transformer import layer_kinds

# per-layer-activation bytes factors by remat policy (bf16 activations; coarse):
# how many bytes of saved residuals per token per d_model unit
_ACT_FACTOR = {"none": 18.0, "dots": 8.0, "full": 4.0}


@dataclass
class Terms:
    flops_total: float          # whole-step, all chips
    flops_per_device: float
    hbm_bytes_per_device: float
    state_bytes_per_device: float   # resident: params + opt (+cache for serving)
    t_compute: float
    t_memory: float


def _moe_cf(cfg: ModelConfig, pcfg: ParallelConfig) -> float:
    return pcfg.capacity_factor if pcfg.capacity_factor is not None \
        else cfg.capacity_factor


def layer_flops_per_token(cfg: ModelConfig, kind: str, s_ctx: float,
                          pcfg: ParallelConfig) -> float:
    """Forward FLOPs per token for one layer of the given mixer kind.
    ``s_ctx`` = average attended context length (S/2 causal, window, or cache len).
    """
    d = cfg.d_model
    fl = 0.0
    if kind in ("attn", "local", "moe"):
        h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        fl += 2.0 * d * hd * (2 * h + 2 * hk)           # qkvo projections
        fl += 2.0 * 2.0 * h * hd * s_ctx                # qk^T and pv
    if kind in ("attn", "local"):
        fl += 3 * 2.0 * d * cfg.d_ff                    # gated mlp
    if kind == "moe":
        cf = _moe_cf(cfg, ParallelConfig())
        fl += 2.0 * d * cfg.num_experts                 # router
        fl += cfg.experts_per_token * 3 * 2.0 * d * cfg.d_ff * cf
    if kind == "ssm":
        di = cfg.ssm_expand * d
        h_ = di // cfg.ssm_head_dim
        n, p, l = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_chunk
        fl += 2.0 * d * (2 * di + 2 * n + h_) + 2.0 * di * d
        fl += 2.0 * cfg.ssm_conv * (di + 2 * n)
        fl += 2.0 * l * n + 2.0 * l * h_ * p + 4.0 * h_ * n * p
    if kind == "rglru":
        w = cfg.lru_width or d
        fl += 3 * 2.0 * d * w + 2 * 2.0 * w * w + 7.0 * w
        fl += 3 * 2.0 * d * cfg.d_ff                    # griffin mlp
    return fl


def step_flops_total(cfg: ModelConfig, shape: ShapeConfig,
                     pcfg: ParallelConfig) -> float:
    b, s = shape.global_batch, shape.seq_len
    kinds = layer_kinds(cfg)
    if shape.kind == "decode":
        tokens = float(b)                                # one token per sequence
        ctx = {"attn": float(s), "moe": float(s),
               "local": float(min(s, cfg.local_window)),
               "ssm": 1.0, "rglru": 1.0}
    else:
        prefix = cfg.frontend_tokens if cfg.family == "vlm" else 0
        tokens = float(b) * (s + prefix)
        ctx = {"attn": (s + prefix) / 2.0, "moe": (s + prefix) / 2.0,
               "local": min(cfg.local_window, s / 2.0),
               "ssm": 1.0, "rglru": 1.0}
    per_tok = sum(layer_flops_per_token(cfg, k, ctx.get(k, 1.0), pcfg)
                  for k in kinds)
    per_tok += 2.0 * cfg.d_model * cfg.vocab_size       # lm head
    if cfg.frontend_dim:
        per_tok += 2.0 * cfg.frontend_dim * cfg.d_model
    mult = 3.0 if shape.kind == "train" else 1.0        # fwd+bwd
    if shape.kind == "train" and (cfg.remat == "full" or pcfg.remat == "full"):
        mult += 1.0                                      # recompute fwd
    return per_tok * tokens * mult


def state_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                           pcfg: ParallelConfig, n_params: int, chips: int,
                           opt_bytes_per_param: float, cache_bytes_total: float
                           ) -> float:
    pb = 2.0 if cfg.dtype == "bfloat16" else 4.0
    # params shard over model x (data if fsdp); otherwise only model
    shard = chips if pcfg.fsdp else max(
        1, chips // (shape.global_batch and _dp_size(shape, chips)))
    params_local = n_params * pb / shard
    opt_local = (n_params * opt_bytes_per_param / shard
                 if shape.kind == "train" else 0.0)
    return params_local + opt_local + cache_bytes_total / chips


def _dp_size(shape: ShapeConfig, chips: int) -> int:
    model = 16
    return max(1, chips // model)


def step_hbm_bytes_per_device(cfg: ModelConfig, shape: ShapeConfig,
                              pcfg: ParallelConfig, n_params: int,
                              n_active: int, chips: int,
                              opt_bytes_per_param: float,
                              cache_bytes_total: float) -> float:
    """Coarse HBM traffic model (bf16 weights/activations, fp32 master path)."""
    pb = 2.0 if cfg.dtype == "bfloat16" else 4.0
    b, s = shape.global_batch, shape.seq_len
    act_f = _ACT_FACTOR.get(pcfg.remat if pcfg.remat != "none" else cfg.remat,
                            _ACT_FACTOR["none"])
    if shape.kind == "train":
        params_local = n_params * pb / chips if pcfg.fsdp else \
            n_params * pb / 16
        # read fwd + read bwd (+ re-read under full remat) + grad write fp32
        # + optimizer read/write (mu, nu) + param write
        passes = 3.0 + (1.0 if (pcfg.remat == "full" or cfg.remat == "full") else 0.0)
        traffic = params_local * passes + (n_params / chips) * (
            4.0 + 2.0 * opt_bytes_per_param)
        tokens_local = b * s / _dp_size(shape, chips)
        traffic += tokens_local * cfg.d_model * cfg.num_layers * act_f
        traffic += 3.0 * tokens_local * (cfg.vocab_size / 16) * 4.0  # logits fwd+bwd
        return traffic
    if shape.kind == "prefill":
        params_local = n_params * pb / chips if pcfg.fsdp else n_params * pb / 16
        tokens_local = b * s / _dp_size(shape, chips)
        traffic = params_local \
            + tokens_local * cfg.d_model * cfg.num_layers * 4.0 \
            + cache_bytes_total / chips                 # cache write
        return traffic
    # decode: weights + full cache read per token step (+1 token write)
    touched = n_active if not cfg.num_experts else min(
        n_params,
        n_active + (n_params - n_active) * min(
            1.0, b * cfg.experts_per_token / cfg.num_experts))
    return touched * pb / chips + cache_bytes_total / chips


def cache_bytes_total(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Total decode-cache bytes across the fleet for this shape."""
    if shape.kind == "train":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    pb = 2.0 if cfg.dtype == "bfloat16" else 4.0
    total = 0.0
    for kind in layer_kinds(cfg):
        if kind in ("attn", "moe"):
            total += 2 * b * s * cfg.num_kv_heads * cfg.head_dim * pb
        elif kind == "local":
            total += 2 * b * min(s, cfg.local_window) \
                * cfg.num_kv_heads * cfg.head_dim * pb
        elif kind == "ssm":
            di = cfg.ssm_expand * cfg.d_model
            h_ = di // cfg.ssm_head_dim
            total += b * h_ * cfg.ssm_state * cfg.ssm_head_dim * 4.0
            total += b * (cfg.ssm_conv - 1) * (di + 2 * cfg.ssm_state) * pb
        elif kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            total += b * w * 4.0 + b * 3 * w * pb
    return total


def opt_bytes_per_param(opt_dtype: str, factored: bool) -> float:
    sd = 2.0 if opt_dtype in ("bfloat16", "bf16") else 4.0
    return sd + (0.02 * sd if factored else sd)   # mu + (nu or factored accumulators)


def analytic_terms(cfg: ModelConfig, shape: ShapeConfig, pcfg: ParallelConfig,
                   n_params: int, n_active: int, chips: int,
                   opt_dtype: str = "float32", factored: bool = False,
                   peak_flops: float = 197e12, hbm_bw: float = 819e9) -> Terms:
    obp = opt_bytes_per_param(opt_dtype, factored) if shape.kind == "train" else 0.0
    cache = cache_bytes_total(cfg, shape)
    flops_total = step_flops_total(cfg, shape, pcfg)
    flops_pd = flops_total / chips
    hbm_pd = step_hbm_bytes_per_device(cfg, shape, pcfg, n_params, n_active,
                                       chips, obp, cache)
    state_pd = state_bytes_per_device(cfg, shape, pcfg, n_params, chips, obp, cache)
    return Terms(flops_total=flops_total, flops_per_device=flops_pd,
                 hbm_bytes_per_device=hbm_pd, state_bytes_per_device=state_pd,
                 t_compute=flops_pd / peak_flops, t_memory=hbm_pd / hbm_bw)
