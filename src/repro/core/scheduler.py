"""Immune straggler / shard scheduler — the paper's regulation at the cluster level.

At thousand-node scale the data-parallel step time is the max over workers; a single
straggler drags the fleet. The paper's loop maps directly:

  * immunological memory   — per-worker EMA of observed throughput
  * two-stage regulation   — shard-fraction targets track *memory*, not instantaneous
                             speed (the delay), so transient hiccups don't trigger
                             rebalancing storms
  * hysteresis             — asymmetric up/down tracking damps limit cycles (the
                             oscillation the paper warns redundancy/irrelevancy
                             corrections can produce)
  * anergy / clonal deletion — workers whose memory falls below a floor are marked
                             anergic (excluded: presumed failed / preempted) and
                             revived when throughput returns (elastic membership)

The scheduler is pure JAX state -> state; the trainer consults it for per-worker
microbatch fractions, and the benchmark drives it against simulated heterogeneous
fleets (vs. a static scheduler baseline).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .immune import hysteresis

Array = jax.Array


class SchedulerConfig(NamedTuple):
    mem_decay: float = 0.9        # throughput EMA decay
    up_rate: float = 0.3          # hysteresis: fast to give work back
    down_rate: float = 0.1        # slow to take work away (damps cycles)
    anergy_floor: float = 0.05    # fraction of median speed below which a worker
                                  # is considered failed (anergic)
    revival_steps: int = 3        # consecutive healthy observations to revive
    min_frac: float = 0.0         # floor on a live worker's share


class SchedulerState(NamedTuple):
    mem: Array           # (W,) throughput EMA (tokens/sec units, arbitrary scale)
    frac: Array          # (W,) current shard fractions (sums to 1 over live workers)
    anergic: Array       # (W,) bool — excluded workers
    healthy_count: Array  # (W,) consecutive healthy observations while anergic


def init_scheduler(num_workers: int) -> SchedulerState:
    w = num_workers
    return SchedulerState(
        mem=jnp.ones((w,), jnp.float32),
        frac=jnp.full((w,), 1.0 / w, jnp.float32),
        anergic=jnp.zeros((w,), bool),
        healthy_count=jnp.zeros((w,), jnp.int32),
    )


def observe(state: SchedulerState, throughput: Array,
            cfg: SchedulerConfig = SchedulerConfig()) -> SchedulerState:
    """Update with one step's observed per-worker throughput (0 = no heartbeat)."""
    mem = cfg.mem_decay * state.mem + (1.0 - cfg.mem_decay) * throughput
    live_mem = jnp.where(state.anergic, jnp.nan, mem)
    median = jnp.nan_to_num(jnp.nanmedian(live_mem), nan=1.0)
    # the median alone fails when a *majority* dies (the median is then itself a
    # dead worker) — anchor the health reference to the fastest live worker too
    median = jnp.maximum(median, 0.5 * jnp.nan_to_num(jnp.nanmax(live_mem), nan=1.0))

    # anergy (failure detection) and revival
    looks_dead = mem < cfg.anergy_floor * median
    healthy_now = throughput > 0.5 * median
    healthy_count = jnp.where(state.anergic & healthy_now,
                              state.healthy_count + 1, 0)
    revived = healthy_count >= cfg.revival_steps
    anergic = (state.anergic | looks_dead) & ~revived
    # revive with a fresh (median) memory so they are not instantly re-anergized
    mem = jnp.where(revived, median, mem)

    # regulation: target share proportional to *memory* (delayed), with hysteresis
    live = ~anergic
    weights = jnp.where(live, jnp.maximum(mem, 1e-6), 0.0)
    target = weights / jnp.maximum(jnp.sum(weights), 1e-9)
    target = jnp.where(live, jnp.maximum(target, cfg.min_frac), 0.0)
    target = target / jnp.maximum(jnp.sum(target), 1e-9)
    frac = hysteresis(state.frac, target, cfg.up_rate, cfg.down_rate)
    frac = jnp.where(live, frac, 0.0)
    frac = frac / jnp.maximum(jnp.sum(frac), 1e-9)
    return SchedulerState(mem=mem, frac=frac, anergic=anergic,
                          healthy_count=healthy_count)


def step_time(state: SchedulerState, speeds: Array, work: float = 1.0) -> Array:
    """Simulated wall-time of one DP step: max over live workers of share/speed.

    A fully-anergic fleet has nobody to run the step: the time is ``inf`` (the
    max over an empty set of workers), not 0.0 — returning 0.0 made a dead
    fleet look infinitely fast in ``simulate``."""
    live = ~state.anergic
    t = jnp.where(live, state.frac * work / jnp.maximum(speeds, 1e-9), 0.0)
    return jnp.where(jnp.any(live), jnp.max(t), jnp.inf)


def simulate(speeds_trace: Array, cfg: SchedulerConfig = SchedulerConfig(),
             static: bool = False):
    """Run the scheduler over a (T, W) per-step speed trace; returns per-step times.

    ``static=True`` freezes the uniform assignment — the baseline the immune
    scheduler is compared against."""
    t_steps, w = speeds_trace.shape
    state = init_scheduler(w)

    def body(state, speeds):
        t = step_time(state, speeds)
        new_state = state if static else observe(state, speeds, cfg)
        return new_state, t

    _, times = jax.lax.scan(body, state, speeds_trace)
    return times
