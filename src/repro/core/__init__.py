"""The paper's contribution: immune load-balancing primitives, the agent MIMD model,
the VLSI extraction reproduction, and the ML-layer integrations (router, scheduler)."""
from . import agent_model, immune, router, scheduler  # noqa: F401
