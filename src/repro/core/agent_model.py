"""The Hewes mobile-agent / shared-environment MIMD model (paper §2), vectorized for TPU.

The paper constrains MIMD programs to a finite set of *agent characteristics*, each a
five-subprogram cycle over a shared blackboard memory:

    Pr (read receptive field) -> Pu (state update) -> Pw (write) -> Pa (alter type)
    -> Pm (move)

TPU adaptation (see DESIGN.md §3): agents step *synchronously* (as in Swarm's default
schedule); per-agent MIMD behaviour is realized with ``lax.switch`` under ``vmap`` —
every characteristic is evaluated and the agent's type selects the result. Write
conflicts are resolved with scatter-max (the paper's dominance rule). The blackboard is
an ``(C, H, W)`` int32 array; receptive fields are 3x3 windows.

The framework is generic; ``repro.core.vlsi.extractor`` instantiates it with the paper's
seven agent types.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


class Agents(NamedTuple):
    """SoA agent population. ``state`` layout is defined by the instantiating program."""

    type_id: Array    # (N,)   int32
    prev_type: Array  # (N,)   int32 -- ancestor type (for limit-cycle damping)
    pos: Array        # (N, 2) int32 -- (row, col), kept in the grid interior
    state: Array      # (N, S) int32


class AgentCtx(NamedTuple):
    """Everything one agent may condition on during a cycle (its receptive field)."""

    agent_id: Array   # ()     int32
    n_agents: int
    pos: Array        # (2,)   int32
    state: Array      # (S,)   int32
    prev_type: Array  # ()     int32
    patch: Array      # (C,3,3) int32 -- receptive field, centered on pos
    key: Array        # PRNG key
    step: Array       # ()     int32


class AgentUpdate(NamedTuple):
    """Result of one Pr->Pu->Pw->Pa->Pm cycle for one agent."""

    writes: Array      # (K, 4) int32 -- (channel, row, col, value); max-combined;
                       #                value 0 is a no-op (blackboard values are >= 0)
    state: Array       # (S,)   int32
    new_type: Array    # ()     int32 -- proposed characteristic
    trans_prob: Array  # ()     f32   -- probability the Pa change commits
    pos: Array         # (2,)   int32 -- new receptive-field position


def no_writes(k: int) -> Array:
    return jnp.zeros((k, 4), jnp.int32)


Behavior = Callable[[AgentCtx], AgentUpdate]


class AgentModel:
    """A MIMD program: a finite characteristic set + the shared-environment semantics."""

    def __init__(
        self,
        behaviors: Sequence[Behavior],
        num_channels: int,
        state_size: int,
        writes_cap: int,
        presence_channel: int | None = None,
    ):
        self.behaviors = tuple(behaviors)
        self.num_types = len(behaviors)
        self.num_channels = num_channels
        self.state_size = state_size
        self.writes_cap = writes_cap
        # presence channels [presence_channel, presence_channel + num_types) are rebuilt
        # every cycle with per-type agent counts -- the "cytokine" by which agents sense
        # neighbouring populations (suppression / co-stimulation heuristics).
        self.presence_channel = presence_channel

    # -- Pr ---------------------------------------------------------------
    def _read_patch(self, grid: Array, pos: Array) -> Array:
        """3x3 receptive field. Positions are kept in [1, H-2] x [1, W-2] so the
        window never leaves the grid (layouts carry an empty margin)."""
        return jax.lax.dynamic_slice(grid, (0, pos[0] - 1, pos[1] - 1),
                                     (grid.shape[0], 3, 3))

    def _presence(self, grid: Array, agents: Agents) -> Array:
        if self.presence_channel is None:
            return grid
        base = self.presence_channel
        cleared = jax.lax.dynamic_update_slice(
            grid, jnp.zeros((self.num_types,) + grid.shape[1:], grid.dtype), (base, 0, 0))
        ch = base + agents.type_id
        return cleared.at[ch, agents.pos[:, 0], agents.pos[:, 1]].add(1)

    # -- one full cycle for the whole population --------------------------
    @functools.partial(jax.jit, static_argnums=0)
    def step(self, grid: Array, agents: Agents, key: Array, t: Array):
        n = agents.type_id.shape[0]
        grid = self._presence(grid, agents)
        patches = jax.vmap(lambda p: self._read_patch(grid, p))(agents.pos)  # (N,C,3,3)

        ids = jnp.arange(n, dtype=jnp.int32)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(ids)

        def one(i, pos, state, prev, patch, k):
            ctx = AgentCtx(agent_id=i, n_agents=n, pos=pos, state=state, prev_type=prev,
                           patch=patch, key=k, step=t)
            return jax.lax.switch(agents.type_id[i], self.behaviors, ctx)

        upd: AgentUpdate = jax.vmap(one)(ids, agents.pos, agents.state,
                                         agents.prev_type, patches, keys)

        # -- Pw: dominance semantics — scatter-max, value 0 is the identity.
        w = upd.writes.reshape(-1, 4)
        ch = jnp.clip(w[:, 0], 0, grid.shape[0] - 1)
        r = jnp.clip(w[:, 1], 0, grid.shape[1] - 1)
        c = jnp.clip(w[:, 2], 0, grid.shape[2] - 1)
        v = jnp.maximum(w[:, 3], 0)
        grid = grid.at[ch, r, c].max(v)

        # -- Pa: probabilistic commit (facilitation/inhibition already folded into
        # trans_prob by the behaviours, incl. ancestor damping).
        u = jax.vmap(lambda k: jax.random.uniform(k))(
            jax.vmap(lambda k: jax.random.fold_in(k, 7))(keys))
        commit = (u < upd.trans_prob) & (upd.new_type != agents.type_id)
        new_type = jnp.where(commit, upd.new_type, agents.type_id)
        prev_type = jnp.where(commit, agents.type_id, agents.prev_type)

        # -- Pm: clip receptive fields to the interior.
        pos = jnp.stack([jnp.clip(upd.pos[:, 0], 1, grid.shape[1] - 2),
                         jnp.clip(upd.pos[:, 1], 1, grid.shape[2] - 2)], axis=1)

        return grid, Agents(new_type.astype(jnp.int32), prev_type.astype(jnp.int32),
                            pos.astype(jnp.int32), upd.state.astype(jnp.int32))

    # -- drivers -----------------------------------------------------------
    def population(self, agents: Agents) -> Array:
        return jnp.sum(jax.nn.one_hot(agents.type_id, self.num_types, dtype=jnp.int32),
                       axis=0)

    @functools.partial(jax.jit, static_argnums=(0, 4),
                       static_argnames=("done_fn", "record"))
    def run_scan(self, grid: Array, agents: Agents, key: Array, steps: int,
                 done_fn: Callable[[Array], Array] | None = None, record: bool = True):
        """Fixed-length scan; freezes once ``done_fn(grid)`` holds. Records population
        traces (the paper's Fig. 3) and the completion step."""

        def body(carry, t):
            grid, agents, key, done_at = carry
            key, sub = jax.random.split(key)
            done = done_fn(grid) if done_fn is not None else jnp.array(False)
            done_at = jnp.where((done_at < 0) & done, t, done_at)
            frozen = done_at >= 0

            g2, a2 = self.step(grid, agents, sub, t)
            grid = jax.tree.map(lambda a, b: jnp.where(frozen, a, b), grid, g2)
            agents = jax.tree.map(lambda a, b: jnp.where(frozen, a, b), agents, a2)
            out = self.population(agents) if record else jnp.zeros((), jnp.int32)
            return (grid, agents, key, done_at), out

        init = (grid, agents, key, jnp.array(-1, jnp.int32))
        (grid, agents, key, done_at), pops = jax.lax.scan(
            body, init, jnp.arange(steps, dtype=jnp.int32))
        done_at = jnp.where(done_at < 0, steps, done_at)
        return grid, agents, done_at, pops

    @functools.partial(jax.jit, static_argnums=(0, 4, 5))
    def run_while(self, grid: Array, agents: Agents, key: Array, max_steps: int,
                  done_fn: Callable[[Array], Array]):
        """Early-exit driver for completion-time measurements (the paper's Fig. 4)."""

        def cond(carry):
            grid, agents, key, t = carry
            return (t < max_steps) & ~done_fn(grid)

        def body(carry):
            grid, agents, key, t = carry
            key, sub = jax.random.split(key)
            grid, agents = self.step(grid, agents, sub, t)
            return grid, agents, key, t + 1

        grid, agents, key, t = jax.lax.while_loop(
            cond, body, (grid, agents, key, jnp.array(0, jnp.int32)))
        return grid, agents, t


def uniform_random_agents(key: Array, n: int, h: int, w: int, state_size: int,
                          init_type: int = 0) -> Agents:
    """The paper's initial condition: agents uniformly distributed over the environment,
    all of the initial (layer-finder) type."""
    kr, kc = jax.random.split(key)
    rows = jax.random.randint(kr, (n,), 1, h - 1, jnp.int32)
    cols = jax.random.randint(kc, (n,), 1, w - 1, jnp.int32)
    return Agents(
        type_id=jnp.full((n,), init_type, jnp.int32),
        prev_type=jnp.full((n,), -1, jnp.int32),
        pos=jnp.stack([rows, cols], axis=1),
        state=jnp.zeros((n, state_size), jnp.int32),
    )
