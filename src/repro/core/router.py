"""Immune-regulated MoE expert load balancing (the paper's technique at the ML layer).

The mapping (DESIGN.md §5): expert loads are agent *populations*. The router's
selection bias ``b_e`` is regulated state (not a trained parameter):

  * immunological memory  — EMA of observed per-expert load fractions
  * two-stage delayed suppression — a suppressor state ``s_e`` *integrates* the EMA
    overload, and the bias integrates ``-s_e``: overloaded experts are suppressed only
    after the suppressor population builds (T4 -> T8), so transient spikes are not
    punished (the delay the paper argues prevents positive feedback from being
    cancelled outright)
  * tolerance / anergy + IL-2 revival — starved experts (EMA below a floor) receive a
    revival boost so they are not permanently silenced
  * limit-cycle damping — suppressor leak + bias clipping bound the feedback loop

Like DeepSeek-V3's aux-loss-free balancing, the bias enters *selection only* (top-k);
the combine weights use the raw router scores, so the regulation never distorts the
forward values, only the assignment. Baselines implemented for comparison (the paper's
obligation to compare against a baseline): ``aux`` (Switch-style auxiliary loss),
``sign`` (first-order bias update), ``none``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class RouterConfig(NamedTuple):
    mode: str = "immune"        # immune | aux | sign | none
    mem_decay: float = 0.9      # immunological-memory EMA
    couple: float = 2.0         # suppressor build-up rate (per unit overload)
    leak: float = 0.05          # suppressor leak (limit-cycle damping)
    gain: float = 1.0           # bias contribution of the (delayed) suppressor
    prop: float = 10.0          # proportional damping on instantaneous overload
    revival: float = 0.05       # IL-2 boost for starved (anergic) experts
    starve_frac: float = 0.2    # starved = EMA load < starve_frac / E
    bias_clip: float = 4.0
    sign_gamma: float = 0.001   # the 'sign' baseline's step


class RouterState(NamedTuple):
    bias: Array          # (E,) selection bias
    mem: Array           # (E,) EMA of load fractions (immunological memory)
    suppressor: Array    # (E,) delayed negative-feedback population
    steps: Array         # () update count


def init_router_state(num_experts: int) -> RouterState:
    z = jnp.zeros((num_experts,), jnp.float32)
    return RouterState(bias=z, mem=z + 1.0 / num_experts, suppressor=z,
                       steps=jnp.zeros((), jnp.int32))


def update_router_state(state: RouterState, load_frac: Array,
                        cfg: RouterConfig) -> RouterState:
    """One regulation step given the observed per-expert load fractions (sum == 1)."""
    e = load_frac.shape[0]
    target = 1.0 / e
    mem = cfg.mem_decay * state.mem + (1.0 - cfg.mem_decay) * load_frac
    overload = mem - target
    # two-stage: the suppressor population *accumulates* remembered overload (leaky
    # integrator = the T8 build-up delay); the bias is SET from suppressor +
    # a proportional term. A pure double integrator (bias += -gain*s) is marginally
    # unstable and produced exactly the limit cycle the paper warns about — the
    # leak + proportional damping are the paper's oscillation-damping prescription.
    suppressor = (1.0 - cfg.leak) * state.suppressor + cfg.couple * overload
    bias = -(cfg.gain * suppressor + cfg.prop * overload)
    # anergy revival: starved experts get an IL-2-like boost
    starved = mem < cfg.starve_frac * target
    bias = bias + cfg.revival * starved.astype(jnp.float32)
    bias = jnp.clip(bias - jnp.mean(bias), -cfg.bias_clip, cfg.bias_clip)
    if cfg.mode == "sign":
        bias = jnp.clip(state.bias + cfg.sign_gamma * jnp.sign(target - load_frac),
                        -cfg.bias_clip, cfg.bias_clip)
        suppressor = state.suppressor
    elif cfg.mode in ("aux", "none"):
        bias = state.bias  # aux/none do not use a selection bias
        suppressor = state.suppressor
    return RouterState(bias=bias, mem=mem, suppressor=suppressor,
                       steps=state.steps + 1)


def route(logits: Array, bias: Array, k: int):
    """Top-k selection with a selection-only bias.

    logits: (T, E) raw router scores. Returns (indices (T,k), gates (T,k), probs (T,E)).
    Gates come from the *unbiased* scores (bias steers assignment, not values).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    _, idx = jax.lax.top_k(logits.astype(jnp.float32) + bias[None, :], k)
    sel = jnp.take_along_axis(logits.astype(jnp.float32), idx, axis=-1)
    gates = jax.nn.softmax(sel, axis=-1)
    return idx, gates, probs


def load_fractions(idx: Array, num_experts: int) -> Array:
    """Fraction of (token, slot) assignments per expert; sums to 1.

    bincount, not one-hot: a (T·k, E) fp32 one-hot is ~12 GB/layer at 1M tokens x
    384 experts; the scatter-add of ones reduces locally + one tiny (E,) combine."""
    counts = jnp.bincount(idx.reshape(-1), length=num_experts)
    return counts.astype(jnp.float32) / idx.size


def aux_loss(idx: Array, probs: Array, num_experts: int) -> Array:
    """Switch-transformer auxiliary loss: E * sum_e f_e * p_e.

    ``probs``: (..., E) with any leading dims — they are reduced in place (merging
    a DP-sharded leading dim with a reshape forces a cross-device gather)."""
    f = jax.lax.stop_gradient(load_fractions(idx, num_experts))
    p = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return num_experts * jnp.sum(f * p)


def load_cv(load_frac: Array) -> Array:
    """Coefficient of variation of expert loads (0 == perfectly balanced)."""
    mean = jnp.mean(load_frac)
    return jnp.std(load_frac) / jnp.maximum(mean, 1e-9)
