"""Serial oracle extractor (pure numpy) — ground truth for the agent-based extractor.

Semantics (paper §3): each contiguous conductor region on a layer is one node. POLY
overlapping DIFF forms a transistor: the overlap is the gate; it splits the diff wire
into source/drain segments (diff conductor = DIFF & ~POLY; poly conducts through the
gate). A contact connects the METAL1 node to the node of the single other conductor
layer overlapping the contact area. PSEL over a gate makes the device a PFET.

Output mirrors the paper's statement forms:
    FET(pol, s, d, g, l, w)  -- s/d unordered; l = min bbox dim, w = max bbox dim
    EQUIV(a, b)              -- (layer, node) pairs, unordered
"""
from __future__ import annotations

from collections import deque
from typing import NamedTuple

import numpy as np

from .layout import CONTACT, DIFF, M1, M2, POLY, PSEL

# conductor layer indices used in node ids
CONDUCTORS = (M1, M2, POLY, DIFF)


class Fet(NamedTuple):
    pol: str                  # 'n' | 'p'
    sd: frozenset             # {(layer, comp), (layer, comp)} -- source/drain nodes
    g: tuple                  # (layer, comp)
    l: int
    w: int


class Equiv(NamedTuple):
    nodes: frozenset          # {(layer, comp), (layer, comp)}


class Netlist(NamedTuple):
    fets: frozenset
    equivs: frozenset
    num_nodes: int


def conductor_mask(grid: np.ndarray, layer: int) -> np.ndarray:
    if layer == DIFF:
        return (grid[DIFF] > 0) & (grid[POLY] == 0)
    return grid[layer] > 0


def label_components(mask: np.ndarray) -> tuple[np.ndarray, int]:
    """4-connected component labelling; labels 1..n, 0 = background."""
    h, w = mask.shape
    labels = np.zeros((h, w), np.int32)
    n = 0
    for r in range(h):
        for c in range(w):
            if mask[r, c] and labels[r, c] == 0:
                n += 1
                q = deque([(r, c)])
                labels[r, c] = n
                while q:
                    rr, cc = q.popleft()
                    for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                        r2, c2 = rr + dr, cc + dc
                        if 0 <= r2 < h and 0 <= c2 < w and mask[r2, c2] and labels[r2, c2] == 0:
                            labels[r2, c2] = n
                            q.append((r2, c2))
    return labels, n


def extract(grid: np.ndarray) -> Netlist:
    grid = np.asarray(grid)
    comp = {}
    counts = {}
    for layer in CONDUCTORS:
        comp[layer], counts[layer] = label_components(conductor_mask(grid, layer))

    # --- transistors: components of the poly∩diff overlap -------------------------
    gate_mask = (grid[POLY] > 0) & (grid[DIFF] > 0)
    gate_comp, n_gates = label_components(gate_mask)
    fets = set()
    for gid in range(1, n_gates + 1):
        cells = np.argwhere(gate_comp == gid)
        rs, cs = cells[:, 0], cells[:, 1]
        g_node = (POLY, int(comp[POLY][rs[0], cs[0]]))
        sd = set()
        for r, c in cells:
            for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
                r2, c2 = r + dr, c + dc
                if 0 <= r2 < grid.shape[1] and 0 <= c2 < grid.shape[2]:
                    d = comp[DIFF][r2, c2]
                    if d > 0:
                        sd.add((DIFF, int(d)))
        h = int(rs.max() - rs.min() + 1)
        w = int(cs.max() - cs.min() + 1)
        pol = 'p' if grid[PSEL][rs[0], cs[0]] > 0 else 'n'
        fets.add(Fet(pol=pol, sd=frozenset(sd), g=g_node, l=min(h, w), w=max(h, w)))

    # --- contacts: components of the contact plane --------------------------------
    con_comp, n_cons = label_components(grid[CONTACT] > 0)
    equivs = set()
    for cid in range(1, n_cons + 1):
        cells = np.argwhere(con_comp == cid)
        r, c = cells[0]
        m1 = comp[M1][r, c]
        other = None
        for layer in (M2, POLY, DIFF):
            v = comp[layer][r, c]
            if v > 0:
                assert other is None, "design-rule violation: contact over >2 conductors"
                other = (layer, int(v))
        assert m1 > 0 and other is not None, "design-rule violation: dangling contact"
        equivs.add(Equiv(nodes=frozenset({(M1, int(m1)), other})))

    num_nodes = sum(counts[layer] for layer in CONDUCTORS)
    return Netlist(fets=frozenset(fets), equivs=frozenset(equivs), num_nodes=num_nodes)
