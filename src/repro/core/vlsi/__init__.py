"""VLSI layout extraction — the paper's worked example (§3)."""
from . import extractor, layout, reference  # noqa: F401
