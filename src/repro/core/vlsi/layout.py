"""Synthetic CMOS layout generation (paper §3).

A layout is a 6-plane int32 bitmap ``(6, H, W)`` with planes METAL1, METAL2, POLY, DIFF,
PSEL, CONTACT. Wires are filled rectangles; a transistor is formed wherever POLY overlaps
DIFF (the overlap is the gate / channel region and splits the diff wire); a contact
electrically connects METAL1 to exactly one other overlapping layer (design rule: no
direct poly-diff contacts).

We generate standard-cell-like layouts programmatically:
  * ``nand_cell``   — the paper's 4-transistor NAND (2 parallel PFETs, 2 series NFETs)
  * ``inverter_cell`` — 2 transistors
  * ``via_cell``    — routing-only cell (m1-m2 via + m1-diff contact), no transistors
  * ``nand_layout`` — one NAND with margin (the paper's Fig. 1 / Fig. 4 workload)
  * ``dff_layout``  — an 8-NAND tile: 32 transistors, >=100 contacts (Fig. 3 scale)
  * ``random_layout`` — random tiling of cells (property tests)

Ground truth is defined by ``repro.core.vlsi.reference`` (the serial oracle).
"""
from __future__ import annotations

import numpy as np

M1, M2, POLY, DIFF, PSEL, CONTACT = range(6)
NUM_LAYERS = 6


class LayoutBuilder:
    def __init__(self, h: int, w: int):
        self.h, self.w = h, w
        self.grid = np.zeros((NUM_LAYERS, h, w), np.int32)

    def rect(self, layer: int, r0: int, c0: int, r1: int, c1: int) -> "LayoutBuilder":
        """Filled rectangle, inclusive coordinates."""
        assert 0 <= r0 <= r1 < self.h and 0 <= c0 <= c1 < self.w, (r0, c0, r1, c1)
        self.grid[layer, r0:r1 + 1, c0:c1 + 1] = 1
        return self

    def contact(self, r: int, c: int, size: int = 2) -> "LayoutBuilder":
        """size x size contact region with upper-left corner (r, c)."""
        return self.rect(CONTACT, r, c, r + size - 1, c + size - 1)

    def paste(self, cell: np.ndarray, r: int, c: int) -> "LayoutBuilder":
        _, ch, cw = cell.shape
        self.grid[:, r:r + ch, c:c + cw] |= cell
        return self


def nand_cell(double_contacts: bool = True) -> np.ndarray:
    """34x26 CMOS NAND: inputs A, B; 2 parallel PFETs (top, under PSEL), 2 series NFETs.

    With ``double_contacts`` the power/output connections use paired contacts — the
    paper notes real layouts connect node pairs through multiple contacts, producing
    redundant equivalence statements (the extractor must tolerate them).
    """
    b = LayoutBuilder(34, 26)
    # polysilicon inputs (width 2, vertical)
    b.rect(POLY, 4, 8, 29, 9)      # input A
    b.rect(POLY, 4, 16, 29, 17)    # input B
    # p-diffusion (top) + select, n-diffusion (bottom)
    b.rect(DIFF, 6, 4, 8, 21)
    b.rect(PSEL, 4, 2, 10, 23)
    b.rect(DIFF, 24, 4, 26, 21)
    # metal1: VDD rail + stubs onto pdiff left/right segments
    b.rect(M1, 1, 0, 2, 25)
    b.rect(M1, 1, 4, 8, 5); b.contact(6, 4)
    b.rect(M1, 1, 20, 8, 21); b.contact(6, 20)
    # metal1: GND rail + stub onto ndiff left segment
    b.rect(M1, 31, 0, 32, 25)
    b.rect(M1, 24, 4, 32, 5); b.contact(24, 4)
    # metal1: output — pdiff middle segment down and across to ndiff right segment
    b.rect(M1, 6, 12, 22, 13); b.contact(6, 12)
    b.rect(M1, 21, 12, 22, 21)
    b.rect(M1, 21, 20, 26, 21); b.contact(24, 20)
    # metal1: inputs A and B contacting the poly lines
    b.rect(M1, 14, 0, 18, 9); b.contact(14, 8)
    b.rect(M1, 14, 16, 18, 25); b.contact(14, 16)
    if double_contacts:
        # enlarged power/output contacts (merge with the base ones into one area each)
        b.contact(7, 4); b.contact(7, 20); b.contact(25, 4); b.contact(7, 12)
        b.contact(25, 20)
        # genuinely redundant (disjoint) contact areas on the same node pairs — the
        # paper notes these produce redundant equivalence statements the extractor
        # emits and the harvester deduplicates.
        b.contact(17, 8)      # second input-A contact (one-row gap from the first)
        b.contact(17, 16)     # second input-B contact
    return b.grid


def inverter_cell() -> np.ndarray:
    """34x18 CMOS inverter: one input poly line, 1 PFET + 1 NFET."""
    b = LayoutBuilder(34, 18)
    b.rect(POLY, 4, 8, 29, 9)
    b.rect(DIFF, 6, 4, 8, 13)
    b.rect(PSEL, 4, 2, 10, 15)
    b.rect(DIFF, 24, 4, 26, 13)
    b.rect(M1, 1, 0, 2, 17)
    b.rect(M1, 1, 4, 8, 5); b.contact(6, 4)
    b.rect(M1, 31, 0, 32, 17)
    b.rect(M1, 24, 4, 32, 5); b.contact(24, 4)
    b.rect(M1, 6, 12, 26, 13); b.contact(6, 12); b.contact(24, 12)
    b.rect(M1, 14, 0, 15, 9); b.contact(14, 8)
    return b.grid


def via_cell() -> np.ndarray:
    """20x16 routing cell: an m1 wire connected to an m2 wire by a via, and to a diff
    stub by a contact. No transistors."""
    b = LayoutBuilder(20, 16)
    b.rect(M1, 4, 2, 5, 13)
    b.rect(M2, 2, 6, 17, 7)
    b.contact(4, 6)                 # m1-m2 via
    b.rect(DIFF, 10, 2, 17, 3)
    b.rect(M1, 4, 2, 11, 3)
    b.contact(10, 2)                # m1-diff contact
    return b.grid


def _with_margin(cell: np.ndarray, margin: int = 3) -> np.ndarray:
    _, h, w = cell.shape
    g = np.zeros((NUM_LAYERS, h + 2 * margin, w + 2 * margin), np.int32)
    g[:, margin:margin + h, margin:margin + w] = cell
    return g


def nand_layout(double_contacts: bool = True) -> np.ndarray:
    """The paper's NAND workload (Fig. 1 / Fig. 4)."""
    return _with_margin(nand_cell(double_contacts))


def dff_layout() -> np.ndarray:
    """Fig.-3-scale workload: 2x4 tile of NANDs -> 32 transistors, 72 contact areas.

    (The paper's D-flip-flop has 32 transistors and 120 contacts; we match the
    transistor count exactly and the contact count in scale — population dynamics
    depend on workload volume, not on inter-cell routing.)
    """
    cell = nand_cell(double_contacts=True)
    _, ch, cw = cell.shape
    rows, cols, gap, margin = 2, 4, 6, 3
    h = margin * 2 + rows * ch + (rows - 1) * gap
    w = margin * 2 + cols * cw + (cols - 1) * gap
    b = LayoutBuilder(h, w)
    for i in range(rows):
        for j in range(cols):
            b.paste(cell, margin + i * (ch + gap), margin + j * (cw + gap))
    return b.grid


def random_layout(rng: np.random.Generator, rows: int = 1, cols: int = 2) -> np.ndarray:
    """Random tiling of well-formed cells — used by property tests."""
    cells = [nand_cell(True), nand_cell(False), inverter_cell(), via_cell()]
    ch = max(c.shape[1] for c in cells)
    cw = max(c.shape[2] for c in cells)
    gap, margin = 6, 3
    h = margin * 2 + rows * ch + (rows - 1) * gap
    w = margin * 2 + cols * cw + (cols - 1) * gap
    b = LayoutBuilder(h, w)
    for i in range(rows):
        for j in range(cols):
            cell = cells[rng.integers(len(cells))]
            b.paste(cell, margin + i * (ch + gap), margin + j * (cw + gap))
    return b.grid
