"""The paper's MIMD layout extractor: seven agent characteristics + immune load balancing.

Agent types (paper §3.1-3.2) and their load-balancing behaviours:

  0 LAYER_FINDER   raster-scans for unlabelled wire cells. Redundancy: suppressed into a
                   node propagator only by *multi-stage delayed suppression* — when both
                   a node-director mark (2 generations downstream) and propagator
                   presence appear in its receptive field.
  1 NODE_LABELLER  walks its wire writing ``label := max(label, own)`` (dominance by
                   scatter-max). Dominated (reads a higher label) -> layer finder.
                   Complete (no lower-labelled wire cells seen for PATIENCE cycles) ->
                   node director (or fet labeller on DIFF). Labels are never reused:
                   ``label = episode * N + id + 1`` (the paper's uniqueness rule — a
                   completing labeller and its descendants cannot relabel with the
                   same ID).
  2 FET_LABELLER   traces DIFF wires marking poly∩diff gate regions (claim by
                   scatter-max of its ID). Dominated -> layer finder (the paper's
                   "second generation" rebound).
  3 FET_OUTPUT     waits at a marked gate until the poly + both diff-side labels are
                   *stable* (the paper's synchronization-by-signal: emit only once the
                   observed labels stop changing), then emits the FET record and flushes
                   done-flags.
  4 CONTACT_FINDER sits on a contact area until both overlapping layers are labelled and
                   stable, emits the equivalence record. Redundancy: losing a contact
                   claim -> node propagator.
  5 NODE_DIRECTOR  retraces a completed wire writing director marks — the delayed
                   third-stage signal that suppresses layer finders and guides
                   propagators.
  6 NODE_PROPAGATOR helper/communication type (APC analogue): max-diffuses labels into
                   wire interiors, converts to contact finder / fet output on demand,
                   and *heals* records that a later dominance wave made stale (all record
                   channels are monotone under max-combining, so healing converges).
                   Anti-crowding movement + epsilon-random walk damp limit cycles and
                   keep exploration ergodic.

All writes are non-negative and max-combined (dominance). The observer-side ``done_fn``
plays Swarm's observer role: termination when every conductor cell is labelled, labels
are a max-diffusion fixpoint, every gate/contact region carries a record, and every
record agrees with the fixpoint labels (exact, vectorized consistency check).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..agent_model import AgentCtx, AgentModel, Agents, AgentUpdate, uniform_random_agents
from ..immune import damp_ancestor_transition
from . import reference
from .layout import CONTACT, DIFF, M1, M2, POLY, PSEL

# ---------------------------------------------------------------------------
# blackboard channels
# ---------------------------------------------------------------------------
LAB0 = 6                     # 6..9: labels for M1, M2, POLY, DIFF
DIRECTOR_MARK = 10
FET_MARK, FET_DONE, FET_S, FET_D, FET_G = 11, 12, 13, 14, 15
# gate bounding-box record, encoded so every corner is monotone under max-combining:
# IR0 = BIG - min_row, IC0 = BIG - min_col, R1 = max_row, C1 = max_col
FET_IR0, FET_IC0, FET_R1, FET_C1 = 16, 17, 18, 19
CON_CLAIM, CON_DONE, CON_A, CON_B = 20, 21, 22, 23
PRESENCE = 24                # 24..30: per-type agent presence ("cytokines")
NUM_CHANNELS = 31
BIG = 1 << 20

# agent types
FINDER, LABELLER, FET_LABELLER, FET_OUTPUT, CONTACT_FINDER, DIRECTOR, PROPAGATOR = range(7)
TYPE_NAMES = ("layer_finder", "node_labeller", "fet_labeller", "fet_output",
              "contact_finder", "node_director", "node_propagator")

# state slots
S_LABEL, S_LAYER, S_TIMER, S_EPISODE, S_HOME_R, S_HOME_C = 0, 1, 2, 3, 4, 5
S_WLAB, S_ELAB, S_MINR, S_MINC, S_MAXR, S_MAXC = 6, 7, 8, 9, 10, 11
S_FLUSH, S_GLAB, S_NLAB, S_SLAB = 12, 13, 14, 15
STATE_SIZE = 16

K_WRITES = 36
PATIENCE_LAB = 10
PATIENCE_FET = 10
DIRECTOR_STEPS = 14
STABLE_WAIT = 8              # cycles the observed labels must hold before emitting
FET_TIMEOUT = 150
CONTACT_TIMEOUT = 150        # starved contact finders anergize back to propagators
ANCESTOR_DAMP = 0.25

# 3x3 window offsets; 4-neighbourhood indices into the flattened window
_WIN = np.stack(np.meshgrid(np.arange(-1, 2), np.arange(-1, 2), indexing="ij"),
                -1).reshape(9, 2)
WIN_OFF = jnp.asarray(_WIN, jnp.int32)                       # (9, 2)
NEIGH = jnp.asarray([1, 3, 5, 7], jnp.int32)                 # N, W, E, S in the window
IDX_N, IDX_W, IDX_E, IDX_S = 1, 3, 5, 7
CENTER = 4


def _flat(patch):
    return patch.reshape(patch.shape[0], 9)                   # (C,3,3) -> (C,9)


def _conductors(p):
    """(C,9) -> (4,9) conductor masks for M1, M2, POLY, DIFF (diff & ~poly)."""
    poly = p[POLY] > 0
    return jnp.stack([p[M1] > 0, p[M2] > 0, poly, (p[DIFF] > 0) & ~poly])


def _labels(p):
    return p[LAB0:LAB0 + 4]                                   # (4,9)


def _gate(p):
    return (p[POLY] > 0) & (p[DIFF] > 0)                      # (9,)


def _win_coords(pos):
    return pos[None, :] + WIN_OFF                             # (9,2)


def _first_idx(mask):
    return mask.any(), jnp.argmax(mask)


def _other_layer_label(cond, labs):
    """Label of the non-m1 conductor under a contact cell (window-flat arrays)."""
    return jnp.max(jnp.where(cond[1:4], labs[1:4], 0), axis=0)  # (9,)


class _W:
    """Accumulates up to K_WRITES (channel, row, col, value) writes; value 0 = no-op."""

    def __init__(self):
        self.items = []

    def put(self, ch, r, c, v):
        self.items.append(jnp.stack([jnp.asarray(ch, jnp.int32),
                                     jnp.asarray(r, jnp.int32),
                                     jnp.asarray(c, jnp.int32),
                                     jnp.asarray(v, jnp.int32)]))

    def put_window(self, ch, coords, vals):
        for i in range(9):
            self.put(ch, coords[i, 0], coords[i, 1], vals[i])

    def pack(self):
        assert len(self.items) <= K_WRITES, len(self.items)
        pad = K_WRITES - len(self.items)
        w = jnp.stack(self.items) if self.items else jnp.zeros((0, 4), jnp.int32)
        if pad:
            w = jnp.concatenate([w, jnp.zeros((pad, 4), jnp.int32)], 0)
        return w


def _walk(ctx: AgentCtx, scores, eps: float = 0.0) -> jax.Array:
    """Pm for walkers: move to the best-scoring 4-neighbour (plus noise); stay if all
    scores are <= 0. With probability ``eps`` take a uniformly random step instead —
    the paper's propagators move randomly when there is nothing to propagate toward,
    and ergodic exploration is what lets them correct stale (dominated) labels."""
    k1, k2, k3 = jax.random.split(jax.random.fold_in(ctx.key, 3), 3)
    noise = jax.random.uniform(k1, (4,))
    total = scores + 0.5 * noise
    best = jnp.argmax(total)
    stay = jnp.max(scores) <= 0.0
    step = jnp.where(stay, ctx.pos, ctx.pos + WIN_OFF[NEIGH[best]])
    if eps > 0.0:
        rnd = ctx.pos + WIN_OFF[NEIGH[jax.random.randint(k2, (), 0, 4)]]
        step = jnp.where(jax.random.uniform(k3) < eps, rnd, step)
    return step


@functools.lru_cache(maxsize=64)
def make_extractor(n_agents: int, grid_hw: tuple[int, int] | None = None,
                   ancestor_damp: float = ANCESTOR_DAMP,
                   finder_suppression: bool = True,
                   walk_eps: float = 0.35):
    """Build the AgentModel implementing the paper's extraction program.

    ``grid_hw`` fixes the raster-scan wrap limits for the layer finders. Memoized so
    repeated runs (speedup sweeps) reuse compiled steps. The keyword knobs exist for
    the heuristic ablations (benchmarks/ablations): ``ancestor_damp=1.0`` disables
    limit-cycle damping, ``finder_suppression=False`` removes the multi-stage
    delayed suppression of layer finders, ``walk_eps=0.0`` removes ergodic
    exploration.
    """
    raster_lim = (grid_hw[0] - 2, grid_hw[1] - 2) if grid_hw else (10 ** 6, 10 ** 6)
    damp = ancestor_damp

    def finder(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        unlab = cond & (labs == 0)
        found, idx = _first_idx(unlab.reshape(-1))
        layer, cell = idx // 9, idx % 9
        coords = _win_coords(ctx.pos)

        # multi-stage delayed suppression: director mark + propagator presence
        suppressed = (p[DIRECTOR_MARK] > 0).any() & (p[PRESENCE + PROPAGATOR].sum() > 0)
        suppressed = suppressed & finder_suppression

        st = ctx.state
        episode = st[S_EPISODE]
        new_label = episode * n_agents + ctx.agent_id + 1
        st_lab = st.at[S_LABEL].set(new_label).at[S_LAYER].set(layer) \
                   .at[S_TIMER].set(0).at[S_EPISODE].set(episode + 1)

        new_type = jnp.where(found, LABELLER, jnp.where(suppressed, PROPAGATOR, FINDER))
        prob = jnp.where(found, 1.0, 0.5)
        prob = damp_ancestor_transition(prob, new_type, ctx.prev_type, damp)
        state = jnp.where(found, st_lab, st)

        # raster scan, stride 3 (window width); labellers start on the found cell
        nc = ctx.pos[1] + 3
        over_c = nc > raster_lim[1]
        nr = jnp.where(over_c, ctx.pos[0] + 3, ctx.pos[0])
        nc = jnp.where(over_c, 1, nc)
        nr = jnp.where(nr > raster_lim[0], 1, nr)
        raster = jnp.stack([nr, nc])
        pos = jnp.where(found, coords[cell], raster)
        return AgentUpdate(_W().pack(), state, new_type, prob, pos)

    def labeller(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        lyr = ctx.state[S_LAYER]
        own = ctx.state[S_LABEL]
        my_cond, my_labs = cond[lyr], labs[lyr]
        coords = _win_coords(ctx.pos)

        dominated = my_cond[CENTER] & (my_labs[CENTER] > own)

        w = _W()
        w.put(LAB0 + lyr, ctx.pos[0], ctx.pos[1], jnp.where(my_cond[CENTER], own, 0))
        # diff labellers mark gate regions as they trace (fet-labelling duty is shared
        # with the dedicated FET_LABELLER type for liveness; see DESIGN.md §8)
        gate_unmarked = _gate(p) & (p[FET_MARK] == 0) & (lyr == DIFF)
        w.put_window(FET_MARK, coords, jnp.where(gate_unmarked, ctx.agent_id + 1, 0))

        work_left = (my_cond & (my_labs < own)).any()
        timer = jnp.where(work_left, 0, ctx.state[S_TIMER] + 1)
        complete = timer > PATIENCE_LAB

        done_type = jnp.where(lyr == DIFF, FET_LABELLER, DIRECTOR)
        new_type = jnp.where(dominated, FINDER, jnp.where(complete, done_type, LABELLER))
        st = ctx.state.at[S_TIMER].set(jnp.where(complete, 0, timer)) \
                      .at[S_FLUSH].set(jnp.where(complete, DIRECTOR_STEPS, 0))
        prob = damp_ancestor_transition(jnp.float32(1.0), new_type, ctx.prev_type,
                                        damp)
        prob = jnp.where(dominated, 1.0, prob)   # dominance losses always convert

        n_cond, n_labs = my_cond[NEIGH], my_labs[NEIGH]
        scores = jnp.where(n_cond, 1.0, -1.0) + 2.0 * (n_cond & (n_labs == 0)) \
            + 1.0 * (n_cond & (n_labs < own))
        return AgentUpdate(w.pack(), st, new_type, prob, _walk(ctx, scores))

    def fet_labeller(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        own = ctx.state[S_LABEL]
        my_cond, my_labs = cond[DIFF], labs[DIFF]
        coords = _win_coords(ctx.pos)

        dominated = my_cond[CENTER] & (my_labs[CENTER] > own)

        w = _W()
        w.put(LAB0 + DIFF, ctx.pos[0], ctx.pos[1], jnp.where(my_cond[CENTER], own, 0))
        gate_unmarked = _gate(p) & (p[FET_MARK] == 0)
        w.put_window(FET_MARK, coords, jnp.where(gate_unmarked, ctx.agent_id + 1, 0))

        timer = jnp.where(gate_unmarked.any(), 0, ctx.state[S_TIMER] + 1)
        complete = timer > PATIENCE_FET
        new_type = jnp.where(dominated, FINDER,
                             jnp.where(complete, PROPAGATOR, FET_LABELLER))
        prob = damp_ancestor_transition(jnp.float32(1.0), new_type, ctx.prev_type,
                                        damp)
        prob = jnp.where(dominated, 0.9, prob)   # paper: *most* dominated ones rebound
        st = ctx.state.at[S_TIMER].set(jnp.where(complete, 0, timer))

        scores = jnp.where(my_cond[NEIGH], 1.0, -1.0)
        return AgentUpdate(w.pack(), st, new_type, prob, _walk(ctx, scores))

    def director(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond = _conductors(p)
        lyr = ctx.state[S_LAYER]
        my_cond = cond[lyr]

        w = _W()
        w.put(DIRECTOR_MARK, ctx.pos[0], ctx.pos[1], jnp.where(my_cond[CENTER], 1, 0))

        flush = ctx.state[S_FLUSH] - 1
        done = flush <= 0
        st = ctx.state.at[S_FLUSH].set(jnp.maximum(flush, 0))
        new_type = jnp.where(done, PROPAGATOR, DIRECTOR)

        unmarked = my_cond[NEIGH] & (p[DIRECTOR_MARK][NEIGH] == 0)
        scores = jnp.where(my_cond[NEIGH], 1.0, -1.0) + 2.0 * unmarked
        return AgentUpdate(w.pack(), st, new_type, jnp.float32(1.0), _walk(ctx, scores))

    def propagator(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        coords = _win_coords(ctx.pos)
        gate = _gate(p)

        # Pw: local max-diffusion of all four label planes (respects the diff/gate
        # barrier because diff conductor excludes gate cells).
        w = _W()
        for lyr in range(4):
            both = cond[lyr, CENTER] & cond[lyr][NEIGH]
            for k in range(4):
                ni = NEIGH[k]
                w.put(LAB0 + lyr, coords[ni, 0], coords[ni, 1],
                      jnp.where(both[k], labs[lyr, CENTER], 0))
            pull = jnp.max(jnp.where(both, labs[lyr][NEIGH], 0))
            w.put(LAB0 + lyr, ctx.pos[0], ctx.pos[1], pull)

        # --- staleness detection (healing): records are monotone max-combined, so a
        # record lagging the dominance wave is re-opened and re-emitted.
        other_lab = _other_layer_label(cond, labs)
        con_stale = (p[CON_A] > 0) & ((p[CON_A] < labs[M1]) | (p[CON_B] < other_lab))
        g_stale_cells = (p[FET_S] > 0) & (p[FET_G] < labs[POLY])
        rec_s, rec_d = jnp.max(p[FET_S]), jnp.max(p[FET_D])
        side = jnp.where(cond[DIFF][NEIGH], labs[DIFF][NEIGH], 0)
        side_stale = gate[CENTER] & (p[FET_DONE][CENTER] > 0) & (rec_s > 0) \
            & ((side > 0) & (side != rec_s) & (side != rec_d)).any()
        # bbox staleness: a gate cell visible outside the bbox implied by a visible
        # record (regions are small enough that record + extreme cell co-occur in
        # some window — see DESIGN.md §8)
        brec = p[FET_R1] > 0
        r1w, c1w = jnp.max(p[FET_R1]), jnp.max(p[FET_C1])
        r0w, c0w = BIG - jnp.max(p[FET_IR0]), BIG - jnp.max(p[FET_IC0])
        outside = gate & ((coords[:, 0] > r1w) | (coords[:, 0] < r0w)
                          | (coords[:, 1] > c1w) | (coords[:, 1] < c0w))
        bbox_stale = brec & (outside.any() & brec.any())

        # Pa: convert on demand (contact finder / fet output / healing / relabelling).
        # Contact claims are honoured only while a contact finder is actually present
        # (presence = the paper's cytokine signal) — a departed claimant cannot
        # deadlock the region.
        cf_present = p[PRESENCE + CONTACT_FINDER][CENTER] > 0
        on_contact = (p[CONTACT][CENTER] > 0) \
            & (((p[CON_DONE][CENTER] == 0)
                & ((p[CON_CLAIM][CENTER] == 0) | ~cf_present))
               | con_stale[CENTER])
        gate_spawn = (gate & (p[FET_MARK] > 0) & (p[FET_DONE] == 0)) \
            | g_stale_cells | bbox_stale
        gate_spawn = gate_spawn.at[CENTER].set(gate_spawn[CENTER] | side_stale)
        has_gate, gidx = _first_idx(gate_spawn)
        has_gate = has_gate & ~on_contact
        seed = coords[gidx]

        # irrelevancy correction: an unlabelled conductor cell whose window holds no
        # same-layer label cannot be fixed by diffusion — become a labeller for it.
        need_label = cond[:, CENTER] & (labs[:, CENTER] == 0) \
            & ~(cond & (labs > 0)).any(axis=1)
        relabel, rl_layer = _first_idx(need_label)
        relabel = relabel & ~on_contact & ~has_gate

        w.put(CON_CLAIM, ctx.pos[0], ctx.pos[1],
              jnp.where(on_contact, ctx.agent_id + 1, 0))

        st = ctx.state
        st_con = st.at[S_HOME_R].set(ctx.pos[0]).at[S_HOME_C].set(ctx.pos[1]) \
                   .at[S_TIMER].set(0).at[S_WLAB].set(0).at[S_ELAB].set(0)
        st_fet = st.at[S_HOME_R].set(seed[0]).at[S_HOME_C].set(seed[1]) \
                   .at[S_WLAB].set(0).at[S_ELAB].set(0).at[S_NLAB].set(0) \
                   .at[S_SLAB].set(0).at[S_GLAB].set(0) \
                   .at[S_MINR].set(seed[0]).at[S_MINC].set(seed[1]) \
                   .at[S_MAXR].set(seed[0]).at[S_MAXC].set(seed[1]) \
                   .at[S_TIMER].set(0).at[S_FLUSH].set(0)
        episode = st[S_EPISODE]
        st_lab = st.at[S_LABEL].set(episode * ctx.n_agents + ctx.agent_id + 1) \
                   .at[S_LAYER].set(rl_layer).at[S_TIMER].set(0) \
                   .at[S_EPISODE].set(episode + 1)
        state = jnp.where(on_contact, st_con,
                          jnp.where(has_gate, st_fet,
                                    jnp.where(relabel, st_lab, st)))
        new_type = jnp.where(on_contact, CONTACT_FINDER,
                             jnp.where(has_gate, FET_OUTPUT,
                                       jnp.where(relabel, LABELLER, PROPAGATOR)))
        prob = damp_ancestor_transition(jnp.float32(1.0), new_type, ctx.prev_type,
                                        damp)
        # Work conversions stay damped when they would return the agent to its
        # ancestor type: undamped respawn loops (fet_output -> propagator ->
        # fet_output on a gate whose side is not yet labelled) were observed to
        # absorb the whole population — the limit-cycle the paper warns about.
        # Presence-gated contact claims make an uncommitted claim harmless.
        prob = jnp.where(relabel, 1.0, prob)

        # Pm: toward work; anti-crowding (diffusion) on own type damps limit cycles
        any_cond = cond.any(0)
        any_unlab = (cond & (labs == 0)).any(0)
        contact_todo = (p[CONTACT] > 0) & (p[CON_DONE] == 0)
        crowd = p[PRESENCE + PROPAGATOR][NEIGH].astype(jnp.float32)
        scores = 0.2 + 1.0 * any_cond[NEIGH] + 2.0 * any_unlab[NEIGH] \
            + 0.3 * (p[DIRECTOR_MARK][NEIGH] > 0) + 2.0 * contact_todo[NEIGH] \
            + 2.0 * gate_spawn[NEIGH] - 0.5 * crowd
        pos = _walk(ctx, scores, eps=walk_eps)
        pos = jnp.where(has_gate, seed, pos)
        pos = jnp.where(on_contact | relabel, ctx.pos, pos)
        return AgentUpdate(w.pack(), state, new_type, prob, pos)

    def contact_finder(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        coords = _win_coords(ctx.pos)

        claim = p[CON_CLAIM][CENTER]
        # a stale claim from a departed finder must not block us: dominance applies
        # only while another claimant is actually co-located (presence cytokine)
        lost = (claim > ctx.agent_id + 1) \
            & (p[PRESENCE + CONTACT_FINDER][CENTER] > 1)

        # pull labels into the contact cell for all layers (it sits on m1 ∩ other)
        w = _W()
        for lyr in range(4):
            both = cond[lyr, CENTER] & cond[lyr][NEIGH]
            pull = jnp.max(jnp.where(both, labs[lyr][NEIGH], 0))
            w.put(LAB0 + lyr, ctx.pos[0], ctx.pos[1], pull)

        m1lab = labs[M1, CENTER]
        olab = _other_layer_label(cond, labs)[CENTER]

        # synchronization: emit only after the pair has been stable for STABLE_WAIT
        changed = (m1lab != ctx.state[S_WLAB]) | (olab != ctx.state[S_ELAB])
        timer = jnp.where(changed, 0, ctx.state[S_TIMER] + 1)
        stale_rec = (p[CON_A][CENTER] > 0) & ((p[CON_A][CENTER] < m1lab)
                                              | (p[CON_B][CENTER] < olab))
        fresh_done = (p[CON_DONE][CENTER] > 0) & ~stale_rec
        # a healing re-emit (stale record) ignores the claim — claims arbitrate only
        # the *first* emission; healing writes are monotone and idempotent
        ready = (m1lab > 0) & (olab > 0) & (timer >= STABLE_WAIT) \
            & (~lost | stale_rec) & ~fresh_done

        w.put(CON_A, ctx.pos[0], ctx.pos[1], jnp.where(ready, m1lab, 0))
        w.put(CON_B, ctx.pos[0], ctx.pos[1], jnp.where(ready, olab, 0))
        con_cells = p[CONTACT] > 0
        w.put_window(CON_DONE, coords, jnp.where(ready & con_cells, 1, 0))

        st = ctx.state.at[S_WLAB].set(m1lab).at[S_ELAB].set(olab).at[S_TIMER].set(timer)
        # anergy: a finder starved of labels for CONTACT_TIMEOUT cycles is doing
        # irrelevant work — revert to propagator (presence-gated claims make the
        # contact re-claimable once the wires are labelled)
        starved = (timer > CONTACT_TIMEOUT) & ~ready
        leave = ready | (lost & ~stale_rec) | fresh_done | starved
        new_type = jnp.where(leave, PROPAGATOR, CONTACT_FINDER)
        return AgentUpdate(w.pack(), st, new_type, jnp.float32(1.0), ctx.pos)

    def fet_output(ctx: AgentCtx) -> AgentUpdate:
        p = _flat(ctx.patch)
        cond, labs = _conductors(p), _labels(p)
        coords = _win_coords(ctx.pos)
        gate = _gate(p)
        st = ctx.state

        # grow the gate-region bounding box from window gate cells
        big = jnp.int32(10 ** 6)
        minr = jnp.minimum(st[S_MINR], jnp.min(jnp.where(gate, coords[:, 0], big)))
        minc = jnp.minimum(st[S_MINC], jnp.min(jnp.where(gate, coords[:, 1], big)))
        maxr = jnp.maximum(st[S_MAXR], jnp.max(jnp.where(gate, coords[:, 0], -1)))
        maxc = jnp.maximum(st[S_MAXC], jnp.max(jnp.where(gate, coords[:, 1], -1)))

        # geometric side-canonical S/D collection: track the max diff label seen on
        # each side (N/W/E/S) of gate cells; the record uses whichever opposite pair
        # is fully labelled. This keeps records consistent across competing emitters
        # and makes staleness locally checkable.
        on_gate = gate[CENTER]
        sides = jnp.where(cond[DIFF][NEIGH] & on_gate, labs[DIFF][NEIGH], 0)  # N,W,E,S
        nlab = jnp.maximum(st[S_NLAB], sides[0])
        wlab = jnp.maximum(st[S_WLAB], sides[1])
        elab = jnp.maximum(st[S_ELAB], sides[2])
        slab = jnp.maximum(st[S_SLAB], sides[3])
        glab = jnp.maximum(st[S_GLAB], jnp.where(on_gate, labs[POLY, CENTER], 0))

        changed = (nlab != st[S_NLAB]) | (wlab != st[S_WLAB]) | (elab != st[S_ELAB]) \
            | (slab != st[S_SLAB]) | (glab != st[S_GLAB]) \
            | (minr != st[S_MINR]) | (minc != st[S_MINC]) \
            | (maxr != st[S_MAXR]) | (maxc != st[S_MAXC])
        timer = jnp.where(changed, 0, st[S_TIMER] + 1)

        we_ok = (wlab > 0) & (elab > 0)
        ns_ok = (nlab > 0) & (slab > 0)
        s_val = jnp.where(we_ok, wlab, nlab)
        d_val = jnp.where(we_ok, elab, slab)

        flush = st[S_FLUSH]
        collecting = flush == 0
        complete = collecting & (we_ok | ns_ok) & (glab > 0) & (timer >= STABLE_WAIT)

        w = _W()
        hr, hc = st[S_HOME_R], st[S_HOME_C]
        w.put(FET_S, hr, hc, jnp.where(complete, s_val, 0))
        w.put(FET_D, hr, hc, jnp.where(complete, d_val, 0))
        w.put(FET_G, hr, hc, jnp.where(complete, glab, 0))
        w.put(FET_IR0, hr, hc, jnp.where(complete, BIG - minr, 0))
        w.put(FET_IC0, hr, hc, jnp.where(complete, BIG - minc, 0))
        w.put(FET_R1, hr, hc, jnp.where(complete, maxr, 0))
        w.put(FET_C1, hr, hc, jnp.where(complete, maxc, 0))
        flushing = complete | (flush > 0)
        w.put_window(FET_DONE, coords, jnp.where(flushing & gate, 1, 0))

        new_flush = jnp.where(complete, 3, jnp.maximum(flush - 1, 0))
        give_up = collecting & (timer > FET_TIMEOUT)
        done = (flush > 0) & (new_flush == 0)

        # a starved fet output usually means an *unlabelled* diff side — convert
        # straight to a labeller for it (irrelevancy correction doing useful work)
        unlab = cond & (labs == 0)
        can_relabel, uidx = _first_idx(unlab.reshape(-1))
        rl_layer, rl_cell = uidx // 9, uidx % 9
        relabel = give_up & can_relabel
        episode = st[S_EPISODE]
        new_type = jnp.where(done | give_up,
                             jnp.where(relabel, LABELLER, PROPAGATOR), FET_OUTPUT)

        st = st.at[S_MINR].set(minr).at[S_MINC].set(minc) \
               .at[S_MAXR].set(maxr).at[S_MAXC].set(maxc) \
               .at[S_NLAB].set(nlab).at[S_WLAB].set(wlab).at[S_ELAB].set(elab) \
               .at[S_SLAB].set(slab).at[S_GLAB].set(glab) \
               .at[S_FLUSH].set(new_flush).at[S_TIMER].set(timer)
        st_lab = st.at[S_LABEL].set(episode * ctx.n_agents + ctx.agent_id + 1) \
                   .at[S_LAYER].set(rl_layer).at[S_TIMER].set(0) \
                   .at[S_EPISODE].set(episode + 1)
        st = jnp.where(relabel, st_lab, st)

        scores = jnp.where(gate[NEIGH], 2.0, -1.0)
        pos = _walk(ctx, scores)
        pos = jnp.where(relabel, coords[rl_cell], pos)
        return AgentUpdate(w.pack(), st, new_type, jnp.float32(1.0), pos)

    behaviors = [finder, labeller, fet_labeller, fet_output, contact_finder,
                 director, propagator]
    return AgentModel(behaviors, NUM_CHANNELS, STATE_SIZE, K_WRITES,
                      presence_channel=PRESENCE)


# ---------------------------------------------------------------------------
# observer: grid construction, termination, harvesting
# ---------------------------------------------------------------------------
def make_grid(layout: np.ndarray) -> jnp.ndarray:
    """(6,H,W) layout -> (NUM_CHANNELS,H,W) blackboard."""
    _, h, w = layout.shape
    grid = np.zeros((NUM_CHANNELS, h, w), np.int32)
    grid[:6] = layout
    return jnp.asarray(grid)


def _shift(x, dr, dc):
    # margins keep wrap-around harmless (border cells are empty)
    return jnp.roll(x, (dr, dc), (0, 1))


def _shift_max(lab, cond):
    """One synchronous max-diffusion step of a label plane within its conductor mask."""
    out = lab
    for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        out = jnp.maximum(out, jnp.where(_shift(cond, dr, dc) & cond,
                                         _shift(lab, dr, dc), 0))
    return out


def _region_max(x, mask, rounds: int = 8):
    """Max-reduce ``x`` over each connected region of ``mask`` (regions here have
    diameter << rounds)."""
    x = jnp.where(mask, x, 0)
    for _ in range(rounds):
        for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
            x = jnp.maximum(x, jnp.where(_shift(mask, dr, dc) & mask,
                                         _shift(x, dr, dc), 0))
    return x


def done_fn(grid) -> jax.Array:
    poly = grid[POLY] > 0
    diff_c = (grid[DIFF] > 0) & ~poly
    conds = [grid[M1] > 0, grid[M2] > 0, poly, diff_c]
    ok = jnp.array(True)
    for lyr in range(4):
        lab, cond = grid[LAB0 + lyr], conds[lyr]
        ok &= jnp.all(~cond | (lab > 0))
        ok &= jnp.all(_shift_max(lab, cond) == lab)

    # FET records: every gate region has one, and it matches the fixpoint side labels.
    gates = poly & (grid[DIFF] > 0)
    dlab = jnp.where(diff_c, grid[LAB0 + DIFF], 0)
    adj = {d: _shift(dlab, dr, dc)
           for d, (dr, dc) in zip("NWES", ((1, 0), (0, 1), (0, -1), (-1, 0)))}
    n_, w_, e_, s_ = (_region_max(adj[d], gates) for d in "NWES")
    we_ok = (w_ > 0) & (e_ > 0)
    sd_hi = jnp.where(we_ok, jnp.maximum(w_, e_), jnp.maximum(n_, s_))
    sd_lo = jnp.where(we_ok, jnp.minimum(w_, e_), jnp.minimum(n_, s_))
    rec = grid[FET_S] > 0
    rec_hi = jnp.maximum(grid[FET_S], grid[FET_D])
    rec_lo = jnp.minimum(grid[FET_S], grid[FET_D])
    rows = jax.lax.broadcasted_iota(jnp.int32, gates.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, gates.shape, 1)
    r1 = _region_max(rows, gates)
    c1 = _region_max(cols, gates)
    ir0 = _region_max(BIG - rows, gates)
    ic0 = _region_max(BIG - cols, gates)
    ok &= jnp.all(~rec | ((rec_hi == sd_hi) & (rec_lo == sd_lo)
                          & (grid[FET_G] == grid[LAB0 + POLY])
                          & (grid[FET_R1] == r1) & (grid[FET_C1] == c1)
                          & (grid[FET_IR0] == ir0) & (grid[FET_IC0] == ic0)))
    ok &= jnp.all(~gates | (_region_max(rec.astype(jnp.int32), gates) > 0))
    ok &= jnp.all(~gates | (grid[FET_DONE] > 0))

    # contact records: per-cell exact (record lives on the contact cell itself)
    con = grid[CONTACT] > 0
    crec = grid[CON_A] > 0
    m2lab = jnp.where(conds[1], grid[LAB0 + M2], 0)
    plab = jnp.where(poly, grid[LAB0 + POLY], 0)
    olab = jnp.maximum(jnp.maximum(m2lab, plab), dlab)
    ok &= jnp.all(~crec | ((grid[CON_A] == grid[LAB0 + M1]) & (grid[CON_B] == olab)))
    ok &= jnp.all(~con | (_region_max(crec.astype(jnp.int32), con) > 0))
    ok &= jnp.all(~con | (grid[CON_DONE] > 0))
    return ok


class SimNetlist(NamedTuple):
    fets: frozenset          # Fet records with sim labels in sd/g node slots
    equivs: frozenset
    label_of: dict           # (layer, oracle_comp) -> set of sim labels on it
    duplicates: int          # redundant records emitted (paper: expected for contacts)


def harvest(grid: np.ndarray, layout: np.ndarray) -> SimNetlist:
    """Read the records the agents wrote to the blackboard and deduplicate them by
    oracle region (the paper's extractor emits redundant statements; the harvester is
    the 'output file' reader)."""
    grid = np.asarray(grid)
    comp = {lyr: reference.label_components(reference.conductor_mask(layout, lyr))[0]
            for lyr in reference.CONDUCTORS}
    gate_mask = (layout[POLY] > 0) & (layout[DIFF] > 0)
    gate_comp, _ = reference.label_components(gate_mask)
    con_comp, _ = reference.label_components(layout[CONTACT] > 0)

    dup = 0
    fets_by_gate: dict[int, tuple] = {}
    for r, c in np.argwhere(grid[FET_S] > 0):
        gid = int(gate_comp[r, c])
        l = int(grid[FET_R1, r, c]) - (BIG - int(grid[FET_IR0, r, c])) + 1
        w = int(grid[FET_C1, r, c]) - (BIG - int(grid[FET_IC0, r, c])) + 1
        rec = (int(grid[FET_S, r, c]), int(grid[FET_D, r, c]), int(grid[FET_G, r, c]),
               l, w, 'p' if layout[PSEL, r, c] > 0 else 'n')
        if gid in fets_by_gate:
            dup += 1
        fets_by_gate[gid] = rec
    fets = frozenset(
        reference.Fet(pol=pol, sd=frozenset({('sim', s), ('sim', d)}), g=('sim', g),
                      l=min(l, w), w=max(l, w))
        for (s, d, g, l, w, pol) in fets_by_gate.values())

    equivs_by_con: dict[int, frozenset] = {}
    for r, c in np.argwhere(grid[CON_A] > 0):
        cid = int(con_comp[r, c])
        pair = frozenset({('sim', int(grid[CON_A, r, c])),
                          ('sim', int(grid[CON_B, r, c]))})
        if cid in equivs_by_con:
            dup += 1
        equivs_by_con[cid] = pair
    equivs = frozenset(reference.Equiv(nodes=p) for p in equivs_by_con.values())

    # oracle-component -> sim-label map (must be consistent & injective for correctness)
    label_of = {}
    for lyr in reference.CONDUCTORS:
        lab_plane = grid[LAB0 + lyr]
        for cid in range(1, comp[lyr].max() + 1):
            vals = set(lab_plane[comp[lyr] == cid].tolist())
            label_of[(lyr, cid)] = vals
    return SimNetlist(fets=fets, equivs=equivs, label_of=label_of, duplicates=dup)


def netlists_equivalent(sim: SimNetlist, oracle: reference.Netlist) -> tuple[bool, str]:
    """Check the agent netlist matches the oracle up to node renaming."""
    mapping = {}
    used = set()
    for (lyr, cid), vals in sim.label_of.items():
        if len(vals) != 1:
            return False, f"component ({lyr},{cid}) has labels {vals}"
        v = next(iter(vals))
        if v == 0:
            return False, f"component ({lyr},{cid}) unlabelled"
        if v in used:
            return False, f"sim label {v} reused across components"
        used.add(v)
        mapping[(lyr, cid)] = ('sim', v)

    o_fets = frozenset(
        reference.Fet(pol=f.pol, sd=frozenset(mapping[n] for n in f.sd),
                      g=mapping[f.g], l=f.l, w=f.w)
        for f in oracle.fets)
    if o_fets != sim.fets:
        return False, f"fets differ: oracle={o_fets - sim.fets} sim={sim.fets - o_fets}"
    o_eq = frozenset(
        reference.Equiv(nodes=frozenset(mapping[n] for n in e.nodes))
        for e in oracle.equivs)
    if o_eq != sim.equivs:
        return False, f"equivs differ: oracle={o_eq - sim.equivs} sim={sim.equivs - o_eq}"
    return True, "ok"


# ---------------------------------------------------------------------------
# one-call drivers
# ---------------------------------------------------------------------------
def run_extraction(layout: np.ndarray, n_agents: int, seed: int = 0,
                   max_steps: int = 4000, record: bool = False):
    """Run the full extraction. Returns (grid, steps_taken, populations|None)."""
    grid = make_grid(layout)
    model = make_extractor(n_agents, (grid.shape[1], grid.shape[2]))
    key = jax.random.PRNGKey(seed)
    ka, kr = jax.random.split(key)
    agents = uniform_random_agents(ka, n_agents, grid.shape[1], grid.shape[2],
                                   STATE_SIZE, init_type=FINDER)
    if record:
        grid, agents, steps, pops = model.run_scan(grid, agents, kr, max_steps,
                                                   done_fn=done_fn, record=True)
        return np.asarray(grid), int(steps), np.asarray(pops)
    grid, agents, steps = model.run_while(grid, agents, kr, max_steps, done_fn)
    return np.asarray(grid), int(steps), None
