"""Immune-system load-balancing primitives (Clark 2022), as composable JAX state machines.

The paper abstracts four mechanisms from the mammalian immune system and argues they are
general load-balancing strategies for MIMD systems:

  * immunological memory      -> ``ImmuneMemory``      (EMA of observed signals)
  * two-stage delayed
    suppression (T4/T8,
    Th1/Th2 regulation)       -> ``TwoStageRegulator`` (fast positive response, delayed
                                                        negative feedback via a second
                                                        population)
  * tolerance / anergy
    (+ IL-2 reactivation)     -> ``AnergyGate``        (suppress responses lacking
                                                        co-stimulation; reversible)
  * dominance                 -> ``dominance_scatter_max`` / ``dominance_resolve``
                                 (contested-resource resolution via max-combining IDs)

All primitives are pure functions over small NamedTuple states so they can live inside
``jax.jit``/``lax.scan`` bodies, be checkpointed as pytrees, and be sharded like any
other training state.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Immunological memory
# ---------------------------------------------------------------------------
class ImmuneMemory(NamedTuple):
    """EMA memory of a signal. ``decay`` plays the role of cytokine half-life."""

    value: Array
    decay: Array  # scalar in [0, 1)

    @staticmethod
    def create(shape, decay: float = 0.99, dtype=jnp.float32) -> "ImmuneMemory":
        return ImmuneMemory(value=jnp.zeros(shape, dtype), decay=jnp.asarray(decay, dtype))

    def update(self, observation: Array) -> "ImmuneMemory":
        new = self.decay * self.value + (1.0 - self.decay) * observation
        return self._replace(value=new)


# ---------------------------------------------------------------------------
# Two-stage delayed regulation (T4 helper / T8 suppressor)
# ---------------------------------------------------------------------------
class RegulatorState(NamedTuple):
    """State of the two-population regulator.

    ``response``   -- the T4-like fast population (what we want to spike quickly).
    ``suppressor`` -- the T8-like population; grows *in response to* ``response`` and
                      only then suppresses it, giving the paper's delayed negative
                      feedback: fast rise, bounded steady state, no simple cancellation.
    """

    response: Array
    suppressor: Array


class TwoStageRegulator(NamedTuple):
    """dr/dt = gain*stimulus + self_excite*r - suppression*s*r - leak_r*r
    ds/dt = couple*r - leak_s*s

    Discretized with explicit Euler (dt folded into the rates). All rates are scalars
    (or broadcastable arrays) so one regulator instance can manage a whole population
    vector (e.g. one response value per MoE expert / per worker).
    """

    gain: Array
    self_excite: Array
    suppression: Array
    couple: Array
    leak_r: Array
    leak_s: Array

    @staticmethod
    def create(
        gain: float = 1.0,
        self_excite: float = 0.15,
        suppression: float = 0.9,
        couple: float = 0.25,
        leak_r: float = 0.05,
        leak_s: float = 0.1,
        dtype=jnp.float32,
    ) -> "TwoStageRegulator":
        a = lambda x: jnp.asarray(x, dtype)
        return TwoStageRegulator(
            gain=a(gain), self_excite=a(self_excite), suppression=a(suppression),
            couple=a(couple), leak_r=a(leak_r), leak_s=a(leak_s),
        )

    def init(self, shape, dtype=jnp.float32) -> RegulatorState:
        return RegulatorState(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))

    def step(self, state: RegulatorState, stimulus: Array) -> RegulatorState:
        r, s = state.response, state.suppressor
        dr = self.gain * stimulus + self.self_excite * r - self.suppression * s * r - self.leak_r * r
        ds = self.couple * r - self.leak_s * s
        r_new = jnp.maximum(r + dr, 0.0)
        s_new = jnp.maximum(s + ds, 0.0)
        return RegulatorState(r_new, s_new)


# ---------------------------------------------------------------------------
# Tolerance / anergy
# ---------------------------------------------------------------------------
class AnergyState(NamedTuple):
    """Per-unit anergy level in [0, 1]; 1 == fully anergic (tolerated / inactive)."""

    level: Array


class AnergyGate(NamedTuple):
    """Tolerance: units whose stimulus arrives *without co-stimulation* become anergic
    (their response is gated off). Anergy is reversible through an IL-2-like revival
    signal, exactly as in peripheral T-cell tolerance.
    """

    onset: Array   # rate anergy builds when stimulus lacks co-stimulation
    revival: Array  # rate anergy decays under the IL-2 revival signal
    floor: Array   # gating at full anergy (0 = hard off)

    @staticmethod
    def create(onset: float = 0.2, revival: float = 0.5, floor: float = 0.0, dtype=jnp.float32):
        a = lambda x: jnp.asarray(x, dtype)
        return AnergyGate(a(onset), a(revival), a(floor))

    def init(self, shape, dtype=jnp.float32) -> AnergyState:
        return AnergyState(jnp.zeros(shape, dtype))

    def step(self, state: AnergyState, stimulus: Array, costimulus: Array,
             il2: Array | float = 0.0) -> AnergyState:
        # Anergy builds where stimulus is present but co-stimulation is absent.
        uncostimulated = jnp.clip(stimulus, 0.0, 1.0) * (1.0 - jnp.clip(costimulus, 0.0, 1.0))
        lvl = state.level + self.onset * uncostimulated * (1.0 - state.level)
        lvl = lvl - self.revival * jnp.asarray(il2) * lvl
        return AnergyState(jnp.clip(lvl, 0.0, 1.0))

    def gate(self, state: AnergyState, response: Array) -> Array:
        scale = 1.0 - (1.0 - self.floor) * state.level
        return response * scale


# ---------------------------------------------------------------------------
# Dominance
# ---------------------------------------------------------------------------
def dominance_scatter_max(grid: Array, rows: Array, cols: Array, values: Array) -> Array:
    """The paper's conflict-resolution rule: ``cell := max(cell, agent_value)``.

    Multiple agents may write the same cell in one cycle; scatter-max makes the highest
    value (e.g. highest agent ID) dominant, deterministically. This is TPU-native (XLA
    scatter with max combiner) — the central heuristic costs one scatter.
    """
    return grid.at[rows, cols].max(values)


def dominance_resolve(ids: Array, claims: Array) -> Array:
    """Resolve ``claims`` (bool, per agent) on a shared scalar resource: only the agent
    with the highest ID among claimants wins. Returns a bool mask of winners (<=1 True).
    """
    claim_ids = jnp.where(claims, ids, -1)
    winner = jnp.max(claim_ids)
    return (claim_ids == winner) & (winner >= 0)


# ---------------------------------------------------------------------------
# Limit-cycle damping
# ---------------------------------------------------------------------------
def damp_ancestor_transition(p: Array, proposed: Array, ancestor: Array,
                             damping: float = 0.1) -> Array:
    """Suppress (but do not disallow) transitions back to an agent's ancestor type.

    The paper notes redundancy-then-irrelevancy corrections can produce limit cycles
    (A->B->A->...); damping the probability of returning to the parent type dampens
    incipient cycles without forbidding legitimate returns.
    """
    is_cycle = proposed == ancestor
    return jnp.where(is_cycle, p * damping, p)


def hysteresis(current: Array, target: Array, up_rate: float, down_rate: float) -> Array:
    """Asymmetric first-order tracking — move quickly toward larger targets, slowly back.

    Used by the straggler scheduler so shard reassignments don't oscillate (the
    scheduling analogue of limit-cycle damping).
    """
    rate = jnp.where(target > current, up_rate, down_rate)
    return current + rate * (target - current)
