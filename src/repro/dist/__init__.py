"""Elastic distribution substrate: checkpointing and sharding.

This package is the fault-tolerance half of the immune load-balancing story: the
scheduler (``repro.core.scheduler``) can mark a worker anergic and take its shard
away, but the fleet only survives that if state can be saved, restored, and laid
out under a *different* device placement than it was written with.

  * ``repro.dist.checkpoint`` — atomic leaf-per-file checkpoints, gathered to host
    so a save from one mesh restores onto any other (elastic resharding).
  * ``repro.dist.sharding``   — NamedSharding trees for params / train state /
    batches / decode caches over the production meshes in ``repro.launch.mesh``.
"""
from . import checkpoint, sharding  # noqa: F401
