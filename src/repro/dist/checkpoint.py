"""Atomic, reshardable pytree checkpoints.

Directory layout (one checkpoint per optimizer step)::

    <ckpt_dir>/
      step_00000010/
        manifest.json          # {"step": 10, "leaves": [{"shape": ..., "dtype": ...}]}
        leaf_00000.npy         # pytree leaves in jax.tree.leaves() order
        leaf_00001.npy
        ...
      step_00000020/
        ...

Semantics:

  * **Atomicity** — a checkpoint is written into a ``step_XXXXXXXX.tmp.*``
    scratch directory and ``os.rename``d into place only once every leaf and the
    manifest are on disk. A crash mid-save leaves a ``.tmp.*`` directory that is
    never considered by ``restore`` (and is swept on the next ``save``); the
    previous checkpoint stays valid.
  * **Elastic resharding** — leaves are gathered to host memory before writing
    (``np.asarray`` on a sharded ``jax.Array`` is a global gather), so the file
    format is placement-free. ``restore`` lays each leaf out to the sharding of
    the corresponding leaf of ``like``: save from a 16x16 mesh, restore onto a
    single host, a 2x16x16 mesh, or anything else that holds the same pytree.
  * **Corruption fallback** — ``restore`` walks checkpoints newest-first and
    returns the first one that fully loads and matches ``like``'s structure;
    truncated/garbage leaves or manifests just skip to the next-older step.
  * **Retention** — ``save(..., keep=k)`` prunes all but the newest ``k``
    checkpoints after the new one is durable.
  * **dtype fidelity** — dtypes outside numpy's native set (bfloat16, float8)
    survive: the manifest records the dtype name and ``restore`` re-views the
    raw buffer, so a bf16 leaf comes back bf16.

Deferred (see ROADMAP "Open items"): async I/O overlapping the next step,
multi-host coordinated saves (per-process shard files + barrier), and
orbax-style partial/lazy restore.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")
_MANIFEST = "manifest.json"

log = logging.getLogger(__name__)


def _fsync_dir(path: str) -> None:
    """fsync a *directory*: durably commit the rename that just landed in it.

    ``os.rename`` updates the parent directory's entries in the page cache;
    without this sync a power loss after the rename can roll the directory
    back to its pre-rename contents, silently losing the checkpoint the
    caller was just told is durable. Best-effort on filesystems that reject
    directory fsync (some network mounts)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _leaf_file(i: int) -> str:
    return f"leaf_{i:05d}.npy"


def all_steps(ckpt_dir: str) -> list[int]:
    """Sorted steps with a (structurally) complete checkpoint directory.

    Read-only. A checkpoint orphaned in a ``.old.`` aside dir by a crash is
    not listed here (``restore`` can still read it; ``save`` renames it back).
    """
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.isfile(os.path.join(ckpt_dir, name, _MANIFEST)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def save(ckpt_dir: str, state: Any, step: int, keep: Optional[int] = None) -> str:
    """Write ``state`` as ``<ckpt_dir>/step_XXXXXXXX``; returns the final path.

    The write is atomic (temp dir + rename); an existing checkpoint at the same
    step is replaced. ``keep`` prunes to the newest ``keep`` checkpoints.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    _sweep_tmp(ckpt_dir)
    leaves = jax.tree.leaves(state)
    host = [np.asarray(jax.device_get(x)) for x in leaves]

    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp.", dir=ckpt_dir)
    try:
        manifest = {"step": int(step), "leaves": []}
        for i, arr in enumerate(host):
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": arr.dtype.name})
            with open(os.path.join(tmp, _leaf_file(i)), "wb") as f:
                np.save(f, arr, allow_pickle=False)
                f.flush()
                os.fsync(f.fileno())
        # the manifest is written LAST: its presence marks the set of leaves
        # complete, so a torn directory can never look like a valid checkpoint
        mpath = os.path.join(tmp, _MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = _step_dir(ckpt_dir, step)
        # re-saving an existing step: move the old dir aside *before* the new
        # rename so there is no instant with zero valid copies of this step
        aside = None
        if os.path.isdir(final):
            aside = tempfile.mkdtemp(prefix=f"step_{step:08d}.old.", dir=ckpt_dir)
            os.rmdir(aside)
            os.rename(final, aside)
        os.rename(tmp, final)
        # fsync the PARENT directory after the rename: without it a crash can
        # roll the directory entry back and lose the checkpoint we just
        # reported durable (the classic rename-without-dirsync gap)
        _fsync_dir(ckpt_dir)
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    if keep is not None and keep > 0:
        # prune relative to the step just written, NOT the max step on disk: a
        # corrupt newer checkpoint we resumed past must never cause the prune
        # to delete the good (older-numbered) checkpoints we are now writing
        older = [s for s in all_steps(ckpt_dir) if s < step]
        for old in older[:max(0, len(older) - (keep - 1))]:
            shutil.rmtree(_step_dir(ckpt_dir, old), ignore_errors=True)
    return final


def restore(ckpt_dir: str, like: Any,
            step: Optional[int] = None) -> tuple[Any, int]:
    """Restore the newest checkpoint that loads cleanly against ``like``.

    ``like`` supplies the pytree structure, per-leaf shapes/dtypes (both
    validated — a dtype change is a structural mismatch, not a silent cast),
    and — when its leaves are committed ``jax.Array``s — the target shardings,
    so one on-disk checkpoint restores under any device placement. Returns
    ``(state, step)``, or ``(None, 0)`` when no checkpoint in ``ckpt_dir`` is
    usable. Corrupt or mismatched checkpoints are skipped (newest-first
    fallback). Passing ``step`` pins the restore to that exact checkpoint
    (no fallback) — used to co-restore sidecar state at a known step.
    """
    like_leaves, treedef = jax.tree.flatten(like)
    # read-only candidate scan: includes checkpoints orphaned in ``.old.``
    # aside dirs by a crash between the renames of a same-step re-save, WITHOUT
    # moving anything — restore may race a live writer (e.g. serve reading a
    # training workdir); recovery-by-rename happens only in save()
    dirs = _candidate_dirs(ckpt_dir)
    candidates = [step] if step is not None else sorted(dirs, reverse=True)
    for s in candidates:
        path = dirs.get(s)
        if path is None:
            continue
        try:
            leaves = _load_step(path, like_leaves)
        except Exception as e:            # corrupt / torn / mismatched: fall back
            log.warning("skipping checkpoint %s: %s: %s",
                        path, type(e).__name__, e)
            continue
        return treedef.unflatten(leaves), s
    if step is None and dirs:
        log.warning("no usable checkpoint among steps %s in %s (all skipped)",
                    sorted(dirs), ckpt_dir)
    return None, 0


def restore_raw(ckpt_dir: str,
                step: Optional[int] = None) -> tuple[Optional[list], int]:
    """Restore the newest checkpoint as a flat list of host numpy arrays.

    The manifest (not a ``like`` tree) drives shapes/dtypes, so callers with
    *dynamic* state — e.g. the serving snapshot, whose pinned-chain leaf
    count varies run to run — can restore without pre-building a matching
    pytree (a first slice of the roadmap's orbax-style lazy restore).
    Returns ``(leaves, step)`` or ``(None, 0)``; corrupt checkpoints fall
    back newest-first like :func:`restore`.
    """
    dirs = _candidate_dirs(ckpt_dir)
    candidates = [step] if step is not None else sorted(dirs, reverse=True)
    for s in candidates:
        path = dirs.get(s)
        if path is None:
            continue
        try:
            with open(os.path.join(path, _MANIFEST)) as f:
                manifest = json.load(f)
            leaves = []
            for i, entry in enumerate(manifest["leaves"]):
                raw = np.load(os.path.join(path, _leaf_file(i)),
                              allow_pickle=False)
                dtype = jnp.dtype(entry["dtype"])
                if raw.dtype != dtype:     # bf16 etc. round-trip as void
                    raw = raw.view(dtype)
                if tuple(raw.shape) != tuple(entry["shape"]):
                    raise ValueError(f"leaf {i}: shape {raw.shape} != "
                                     f"manifest {entry['shape']}")
                leaves.append(raw)
            return leaves, s
        except Exception as e:
            log.warning("skipping checkpoint %s: %s: %s",
                        path, type(e).__name__, e)
            continue
    return None, 0


def _candidate_dirs(ckpt_dir: str) -> dict[int, str]:
    """step -> directory path, preferring final ``step_X`` dirs; an orphaned
    ``step_X.old.*`` aside (final dir missing) is readable in place."""
    out: dict[int, str] = {}
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if ".old." not in name:
            continue
        stem = name.split(".old.")[0]
        m = _STEP_RE.match(stem)
        if (m and not os.path.isdir(os.path.join(ckpt_dir, stem))
                and os.path.isfile(os.path.join(ckpt_dir, name, _MANIFEST))):
            out.setdefault(int(m.group(1)), os.path.join(ckpt_dir, name))
    for s in all_steps(ckpt_dir):
        out[s] = _step_dir(ckpt_dir, s)
    return out


def _load_step(step_dir: str, like_leaves: list) -> list:
    with open(os.path.join(step_dir, _MANIFEST)) as f:
        manifest = json.load(f)
    entries = manifest["leaves"]
    if len(entries) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(entries)} leaves, expected {len(like_leaves)}")
    out = []
    for i, (entry, like_leaf) in enumerate(zip(entries, like_leaves)):
        raw = np.load(os.path.join(step_dir, _leaf_file(i)), allow_pickle=False)
        dtype = jnp.dtype(entry["dtype"])
        if raw.dtype != dtype:            # bf16 etc. round-trip through .npy as V2
            raw = raw.view(dtype)
        if tuple(raw.shape) != tuple(entry["shape"]):
            raise ValueError(f"leaf {i}: shape {raw.shape} != manifest "
                             f"{entry['shape']}")
        if tuple(raw.shape) != tuple(np.shape(like_leaf)):
            raise ValueError(f"leaf {i}: shape {raw.shape} != like "
                             f"{np.shape(like_leaf)}")
        like_dtype = getattr(like_leaf, "dtype", None)
        if like_dtype is not None and jnp.dtype(like_dtype) != dtype:
            raise ValueError(f"leaf {i}: dtype {dtype} != like {like_dtype}")
        out.append(_place_like(raw, like_leaf))
    return out


def _place_like(arr: np.ndarray, like_leaf) -> jax.Array:
    """Device-put a gathered host array to the placement of ``like_leaf``."""
    sharding = getattr(like_leaf, "sharding", None)
    if isinstance(like_leaf, jax.Array) and sharding is not None:
        return jax.device_put(arr, sharding)
    return jnp.asarray(arr)


def _sweep_tmp(ckpt_dir: str) -> None:
    """Clean up after a crash mid-save: drop ``.tmp.`` scratch dirs, and either
    drop or *recover* ``.old.`` aside dirs (a crash between the two renames of a
    same-step re-save leaves the only valid copy in the aside dir — put it
    back rather than deleting it)."""
    for name in os.listdir(ckpt_dir):
        stem = name.split(".tmp.")[0] if ".tmp." in name else \
            name.split(".old.")[0] if ".old." in name else None
        if stem is None or not _STEP_RE.match(stem):
            continue
        path = os.path.join(ckpt_dir, name)
        if ".old." in name and not os.path.isdir(os.path.join(ckpt_dir, stem)):
            os.rename(path, os.path.join(ckpt_dir, stem))
            _fsync_dir(ckpt_dir)
        else:
            shutil.rmtree(path, ignore_errors=True)
