"""NamedSharding trees for the production meshes.

Layout policy (mirrors the activation constraints in ``models/layers.py``):

  * **Tensor parallel ('model' axis)** — attention heads and FFN hidden dims are
    split over 'model'; embeddings split the vocab; MoE expert banks split the
    expert dim when ``pcfg.expert_parallel``.
  * **FSDP ('data' axis)** — when ``pcfg.fsdp``, the *other* matrix dim of each
    weight (and its optimizer moments) is additionally sharded over 'data',
    ZeRO-3 style. The 'pod' axis (multi-pod mesh) stays pure data-parallel.
  * **Batches** — the batch dim shards over ('pod', 'data'); with
    ``pcfg.seq_shard`` the sequence dim of tokens/frames also shards over
    'model' (long-context prefill).
  * **Decode caches** — batch over ('pod', 'data'); KV heads over 'model'
    (or the sequence dim when ``pcfg.seq_shard``).

Every rule passes through the same guard ``layers.constrain`` applies
(``models.layers.guard_entry`` — one implementation, shared): an axis
the mesh doesn't have, or that doesn't divide the dim it would split, is
dropped rather than letting GSPMD pad-and-rematerialize. Leaves with no rule
(small norms/biases, SSM scan constants) are replicated — correct, just not
memory-minimal; see ROADMAP "Open items" for the SSM/rglru FSDP follow-up.

Checkpoints are placement-free (``dist.checkpoint`` gathers leaves to host), so
these shardings are a property of the *run*, not the *artifact*: the same
checkpoint restores under any mesh by passing a ``like`` tree laid out with the
functions here.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey

from ..configs.base import ModelConfig, ParallelConfig
from ..models.layers import guard_entry

_DP = ("pod", "data")          # pure data-parallel axes, filtered to the mesh


def _axes(mesh: Mesh) -> dict[str, int]:
    return dict(mesh.shape)


def _guard(spec: tuple, shape: tuple, axes: dict[str, int]) -> P:
    """Drop axis names the mesh lacks or that don't divide their dim — the same
    ``models.layers.guard_entry`` policy the activation constraints apply, so
    the two layouts cannot drift."""
    return P(*(guard_entry(s, dim, axes) for s, dim in zip(spec, shape)))


def _named(mesh: Mesh, spec: tuple, shape: tuple) -> NamedSharding:
    return NamedSharding(mesh, _guard(spec, shape, _axes(mesh)))


def _dict_names(path) -> list[str]:
    """String dict keys along a tree path (NamedTuple positions carry no names)."""
    return [k.key for k in path if isinstance(k, DictKey) and isinstance(k.key, str)]


def _param_candidate(names: list[str],
                     pcfg: ParallelConfig) -> Optional[tuple]:
    """Unguarded trailing-dims spec for a param leaf, or None to replicate.

    ``names`` are the dict keys on the leaf's path (e.g. [..., 'mixer', 'wq']);
    leading stack/scan dims (depth) are padded with None by the caller.
    """
    if not names:
        return None
    last = names[-1]
    fsdp = "data" if pcfg.fsdp else None
    if last in ("embed", "head", "frontend_proj"):
        return ("model", fsdp)                      # (vocab|in, d_model)
    if "moe" in names:
        ep = "model" if pcfg.expert_parallel else None
        if last in ("w_gate", "w_up"):              # (E, d_model, d_ff)
            return (ep, fsdp, None if pcfg.expert_parallel else "model")
        if last == "w_down":                        # (E, d_ff, d_model)
            return (ep, fsdp if pcfg.expert_parallel else "model", None)
        if last == "w_router":                      # (d_model, E)
            return (fsdp, None)
    if last in ("wq", "wk", "wv"):                  # (d_model, heads*head_dim)
        return (fsdp, "model")
    if last == "wo":                                # (heads*head_dim, d_model)
        return ("model", fsdp)
    if last in ("w_gate", "w_up", "w_gate_branch", "w_x_branch"):
        return (fsdp, "model")                      # (d_model, d_ff|lru_width)
    if last in ("w_down", "w_out"):                 # (d_ff|width, d_model)
        return ("model", fsdp)
    if last == "in_proj":                           # ssm: (d_model, fused_inner)
        return (fsdp, "model")
    if last == "out_proj":                          # ssm: (d_inner, d_model)
        return ("model", fsdp)
    return None


def _param_spec(names: list[str], shape: tuple, mesh: Mesh,
                pcfg: ParallelConfig, trim: int = 0) -> NamedSharding:
    """Full guarded sharding for one leaf. ``trim`` re-derives factored-moment
    specs: 1 drops the rule's last dim (Adafactor 'row'), 2 drops the last two
    and keeps the final one ('col')."""
    cand = _param_candidate(names, pcfg)
    if cand is None:
        return NamedSharding(mesh, P())
    if trim == 1:
        cand = cand[:-1]
    elif trim == 2:
        cand = cand[:-2] + cand[-1:]
    full = (None,) * (len(shape) - len(cand)) + tuple(cand)
    if len(full) != len(shape):                     # rule arity mismatch: replicate
        return NamedSharding(mesh, P())
    return _named(mesh, full, shape)


def param_shardings(params_abs: Any, cfg: ModelConfig, mesh: Mesh,
                    pcfg: ParallelConfig = ParallelConfig()) -> Any:
    """NamedSharding tree matching a ``model.init_params`` pytree.

    ``cfg`` is currently unused (the policy is path-name based) but part of the
    signature for the planned config-aware rules (SSM/rglru FSDP — ROADMAP).
    """
    def leaf(path, x):
        return _param_spec(_dict_names(path), tuple(x.shape), mesh, pcfg)
    return jax.tree_util.tree_map_with_path(leaf, params_abs)


def train_state_shardings(state_abs: Any, cfg: ModelConfig, mesh: Mesh,
                          pcfg: ParallelConfig = ParallelConfig()) -> Any:
    """NamedSharding tree matching a ``train_step.TrainState``.

    Optimizer moments follow their parameter's layout; Adafactor's factored
    second moments ('row'/'col' dicts) inherit the matching slice of it. The
    immune router state and step counters are tiny and replicated.
    """
    def leaf(path, x):
        names = _dict_names(path)
        if names and names[-1] in ("row", "col"):
            trim = 1 if names[-1] == "row" else 2
            return _param_spec(names[:-1], tuple(x.shape), mesh, pcfg, trim=trim)
        return _param_spec(names, tuple(x.shape), mesh, pcfg)
    return jax.tree_util.tree_map_with_path(leaf, state_abs)


def batch_shardings(batch_abs: Any, mesh: Mesh,
                    pcfg: ParallelConfig = ParallelConfig()) -> Any:
    """Batch dim over ('pod','data'); sequence over 'model' with seq_shard."""
    def leaf(path, x):
        names = _dict_names(path)
        seq = "model" if (pcfg.seq_shard and names
                          and names[-1] in ("tokens", "frames")) else None
        spec = (_DP, seq) + (None,) * (x.ndim - 2) if x.ndim >= 2 else (_DP,)
        return _named(mesh, spec[:x.ndim], tuple(x.shape))
    return jax.tree_util.tree_map_with_path(leaf, batch_abs)


def cache_shardings(cache_abs: Any, cfg: ModelConfig, mesh: Mesh,
                    pcfg: ParallelConfig = ParallelConfig()) -> Any:
    """Decode-cache layout: (depth, batch, seq, kv_heads, head_dim) KV leaves
    shard batch over ('pod','data') and KV heads (or the sequence, under
    seq_shard) over 'model'; SSM/rglru recurrent states shard batch only."""
    def leaf(path, x):
        names = _dict_names(path)
        if names and names[-1] in ("k", "v") and x.ndim == 5:
            if pcfg.seq_shard:
                spec = (None, _DP, "model", None, None)
            else:
                spec = (None, _DP, None, "model", None)
        elif x.ndim >= 2:
            spec = (None, _DP) + (None,) * (x.ndim - 2)
        else:
            return NamedSharding(mesh, P())        # 'pos' scalar
        return _named(mesh, spec, tuple(x.shape))
    return jax.tree_util.tree_map_with_path(leaf, cache_abs)
