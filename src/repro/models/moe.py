"""Mixture-of-Experts FFN with capacity-based sort dispatch and immune load balancing.

Dispatch is the TPU-friendly sort/scatter form (not the O(T·E·C) one-hot einsum):
tokens' (token, slot) assignments are sorted by expert, ranked within their expert,
dropped beyond capacity (tolerance: the router's capacity factor is the anergy
threshold), scattered into an (E, C, D) buffer, pushed through a *grouped* matmul
(batched over E — the Pallas ``moe_gmm`` kernel implements the same contract on TPU),
and combined back with gates from the unbiased router scores.

Expert-parallel sharding: the (E, ...) dims shard over the 'model' mesh axis
(dist/sharding.py); XLA inserts the all-to-alls for the scatter/gather.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import router as irouter
from .layers import DP, constrain, dense_init, dtype_of

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    kr, kg, ku, kd = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    scale = 1.0 / jnp.sqrt(d)
    return {
        "w_router": dense_init(kr, d, e, jnp.float32),
        "w_gate": (jax.random.normal(kg, (e, d, f), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ku, (e, d, f), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(kd, (e, f, d), jnp.float32)
                   / jnp.sqrt(f)).astype(dt),
    }


class MoEStats(NamedTuple):
    load_frac: Array     # (E,) observed load fractions
    aux_loss: Array      # () Switch aux loss (used when router_mode == 'aux')
    drop_frac: Array     # () fraction of assignments dropped at capacity


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(cfg.capacity_factor * tokens * cfg.experts_per_token / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def _dispatch(tok, idx, e: int, c: int):
    """Sort-based *gather-only* dispatch for one token group.

    No scatters: GSPMD lowers sharded scatter/scatter-add by replicating the
    operand and all-reducing the result (we measured 18 GiB/step of that on the
    40-expert arch); gathers partition cleanly. Returns
    (expert_in (E,C,D), slot_loc (T*k,), keep (T*k,)) with slot_loc in *unsorted*
    (token-major) order."""
    t, d = tok.shape
    k = idx.shape[-1]
    flat_e = idx.reshape(-1)                                       # (T*k,)
    token_id = jnp.repeat(jnp.arange(t), k)

    # stable sort by expert; rank within expert = position - expert start offset
    order = jnp.argsort(flat_e, stable=True)
    inv_order = jnp.argsort(order, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts

    # gather tokens into the (E, C, D) buffer: buffer row (e_, c_) holds the
    # c_-th assignment of expert e_, i.e. sorted position starts[e_] + c_
    rows = jnp.arange(e)[:, None]
    cols = jnp.arange(c)[None, :]
    sorted_pos = starts[rows] + cols                               # (E, C)
    valid = cols < jnp.minimum(counts[rows], c)
    src_tok = jnp.where(valid, token_id[order[jnp.clip(sorted_pos, 0, t * k - 1)]],
                        t)                                         # pad row
    tok_pad = jnp.concatenate([tok, jnp.zeros((1, d), tok.dtype)], 0)
    expert_in = tok_pad[src_tok]                                   # (E, C, D)

    # per-slot buffer location in unsorted order (for the combine gather)
    rank_unsorted = (jnp.arange(t * k) - starts[sorted_e])[inv_order]
    keep = rank_unsorted < c
    slot_loc = jnp.where(keep, flat_e * c + rank_unsorted, e * c)
    return expert_in, slot_loc, keep


def _combine(out, slot_loc, gates, keep, t: int):
    """Gather-only combine: y[t] = sum_k gate * out[slot_loc[t,k]]."""
    e_c, d = out.shape[0] * out.shape[1], out.shape[2]
    k = gates.shape[-1]
    out_flat = jnp.concatenate([out.reshape(e_c, d),
                                jnp.zeros((1, d), out.dtype)], axis=0)
    slot_out = out_flat[slot_loc].reshape(t, k, d)
    w = (gates * keep.reshape(t, k)).astype(out.dtype)
    return jnp.einsum("tkd,tk->td", slot_out, w)


def moe_ffn(params, x: Array, cfg: ModelConfig, bias: Array):
    """x: (B, S, D) -> (y, MoEStats). ``bias`` is the immune router's selection bias.

    Tokens are dispatched within ``cfg.dispatch_groups`` independent groups; with
    G = DP shard count the argsort/scatter stay device-local and the only cross-
    device traffic is the expert all-to-all (E over 'model')."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    g = cfg.dispatch_groups if t % cfg.dispatch_groups == 0 else 1
    tl = t // g
    tok = constrain(x.reshape(g, tl, d), DP, None, None)

    logits = tok.astype(jnp.float32) @ params["w_router"]          # (G, Tl, E)
    idx, gates, probs = jax.vmap(lambda lg: irouter.route(lg, bias, k))(logits)

    c = capacity(cfg, tl)
    expert_in, slot_loc, keep = jax.vmap(
        lambda tk, ix: _dispatch(tk, ix, e, c))(tok, idx)

    # expert-parallel grouped matmul: E over 'model' (XLA inserts the all-to-all),
    # groups stay on their DP shards
    expert_in = constrain(expert_in, DP, "model", None, None)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = constrain(h, DP, "model", None, None)
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])        # (G, E, C, D)
    # the return all-to-all, made explicit: reshard each group's expert buffer back
    # to its DP shard *before* the combine gather. Gathering straight from the
    # E-sharded buffer lowers as a full-size fp32 all-reduce of the (T·k, D) slot
    # tensor (measured 3.4 TB/step on kimi-k2); this reshard is the bf16 capacity
    # buffer only — the theoretical EP return volume.
    out = constrain(out, DP, None, None, None)

    y = jax.vmap(lambda o, sl, gt, kp: _combine(o, sl, gt, kp, tl))(
        out, slot_loc, gates, keep)
    y = constrain(y, DP, None, None)

    load = irouter.load_fractions(idx, e)
    stats = MoEStats(
        load_frac=load,
        # keep the group dim intact: reshaping (G, Tl, E) -> (T, E) merges a
        # DP-sharded dim and forces a 6 GB/layer gather of the fp32 router probs
        aux_loss=irouter.aux_loss(idx, probs, e),
        drop_frac=1.0 - jnp.mean(keep.astype(jnp.float32)),
    )
    return y.reshape(b, s, d), stats


def moe_ffn_reference(params, x: Array, cfg: ModelConfig, bias: Array):
    """Dense one-hot reference (O(T·E) memory) — oracle for tests, small shapes only.
    No capacity limit: equals moe_ffn exactly when nothing is dropped."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    tok = x.reshape(-1, d)
    logits = tok.astype(jnp.float32) @ params["w_router"]
    idx, gates, _ = irouter.route(logits, bias, k)
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = act(jnp.einsum("td,edf->tef", tok, params["w_gate"])) \
        * jnp.einsum("td,edf->tef", tok, params["w_up"])
    full = jnp.einsum("tef,efd->ted", h, params["w_down"])         # (T, E, D)
    sel = jnp.take_along_axis(full, idx[:, :, None], axis=1)       # (T, k, D)
    y = jnp.sum(sel * gates[:, :, None].astype(sel.dtype), axis=1)
    return y.reshape(b, s, d)
