"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / VLM / audio decoder backbones."""
from . import frontends, layers, model, moe, rglru, ssm, transformer  # noqa: F401
