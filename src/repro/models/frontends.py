"""Modality frontend stubs for the VLM / audio architectures.

Per the assignment, ``[vlm]``/``[audio]`` entries specify the transformer *backbone*
only — the modality frontend (SigLIP vision tower, EnCodec codec) is a stub whose
``input_specs()`` provides precomputed patch/frame embeddings. Here we keep only the
learned projection from frontend embedding space into the backbone's d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, dtype_of

Array = jax.Array


def init_frontend(key, cfg: ModelConfig) -> dict:
    return {"proj": dense_init(key, cfg.frontend_dim, cfg.d_model, dtype_of(cfg))}


def project_frontend(params, emb: Array) -> Array:
    """(B, P, frontend_dim) precomputed embeddings -> (B, P, d_model) prefix."""
    return emb.astype(params["proj"].dtype) @ params["proj"]
