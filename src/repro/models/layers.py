"""Building-block layers (pure JAX, no framework): norms, rotary, attention, MLPs.

Conventions:
  * params are nested dicts of jnp arrays; init_* builds them, apply is a pure fn
  * activations follow ``cfg.dtype`` (bf16 on TPU); softmax/normalization in fp32
  * attention supports MHA / GQA / MQA (num_kv_heads), optional qk-norm, optional
    local (sliding-window) masking, and a KV-cache decode path
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Array = jax.Array


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# mesh-aware activation sharding constraints
# ---------------------------------------------------------------------------
DP = ("pod", "data")   # logical data-parallel axes (filtered to the live mesh)

# Axis names/sizes the current launcher's mesh provides. Classic `with mesh:`
# contexts do not populate jax.sharding.get_abstract_mesh(), so launchers (dryrun,
# train, serve) declare their mesh explicitly via set_mesh_axes(); CPU unit tests
# leave this empty and every constraint is a no-op.
_MESH_AXES: dict[str, int] = {}


def set_mesh_axes(axes, sizes=None) -> None:
    global _MESH_AXES
    if hasattr(axes, "shape") and hasattr(axes.shape, "keys"):  # a Mesh
        _MESH_AXES = dict(axes.shape)
    elif sizes is not None:
        _MESH_AXES = dict(zip(tuple(axes), tuple(sizes)))
    else:
        _MESH_AXES = {a: 0 for a in axes}      # sizes unknown: no divisibility check


def _current_axes() -> dict[str, int]:
    if _MESH_AXES:
        return _MESH_AXES
    try:
        m = jax.sharding.get_abstract_mesh()
        return dict(m.shape) if m is not None and m.axis_names else {}
    except Exception:
        return {}


def guard_entry(s, dim: int, axes: dict[str, int]):
    """One PartitionSpec entry of the shared axis-drop policy.

    This is THE guard — used by the activation constraints here and by
    ``dist.sharding``'s NamedSharding rules, so the two layout policies cannot
    drift. Axis names the mesh doesn't have are dropped; an entry whose
    surviving axes' total size doesn't divide the dim it would split is dropped
    whole (splitting anyway forces GSPMD into pad-and-rematerialize). Axis
    sizes recorded as 0 mean "unknown" and skip the divisibility check.
    Returns None, an axis name, or a tuple of axis names."""
    if s is None:
        return None
    is_seq = isinstance(s, (tuple, list))
    cand = tuple(a for a in (s if is_seq else (s,)) if a in axes)
    if not cand:
        return None
    size, known = 1, True
    for a in cand:
        if axes[a]:
            size *= axes[a]
        else:
            known = False
    if known and dim % size != 0:
        return None
    return cand if is_seq else cand[0]


def constrain(x: Array, *spec) -> Array:
    """with_sharding_constraint that no-ops outside a mesh, drops axis names the
    current mesh doesn't have, and drops axes that don't divide their dim (an
    8-kv-head tensor constrained over a 16-way axis forces GSPMD into involuntary
    full rematerialization — observed, not hypothetical)."""
    axes = _current_axes()
    if not axes:
        return x

    filtered = tuple(guard_entry(s, d, axes) for s, d in zip(spec, x.shape))
    if all(s is None for s in filtered):
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*filtered))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype) -> Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(dim: int) -> dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(params, x: Array, cfg: ModelConfig) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    # zeros-init scale applied as (1 + g) — covers both the llama and gemma
    # conventions (they differ only in checkpoint layout, which we do not load)
    return (xf * (params["scale"] + 1.0)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # (..., S, 1, half): broadcast over the head dimension
    angles = positions[..., :, None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kq, d, h * hd, dt),
        "wk": dense_init(kk, d, hk * hd, dt),
        "wv": dense_init(kv, d, hk * hd, dt),
        "wo": dense_init(ko, h * hd, d, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd)
        p["k_norm"] = init_rmsnorm(hd)
    return p


def _qkv(params, x: Array, cfg: ModelConfig, positions: Array):
    b, s, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    # heads shard over 'model' (padded when h % tp != 0 — local waste, no gather);
    # kv heads follow q heads (GQA groups stay co-located)
    q = constrain((x @ params["wq"]).reshape(b, s, h, hd), DP, None, "model", None)
    k = constrain((x @ params["wk"]).reshape(b, s, hk, hd), DP, None, "model", None)
    v = constrain((x @ params["wv"]).reshape(b, s, hk, hd), DP, None, "model", None)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg)
        k = rmsnorm(params["k_norm"], k, cfg)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Array, cfg: ModelConfig) -> Array:
    """q: (B,Sq,H,D); k,v: (B,Skv,Hkv,D); mask: (B|1, Sq, Skv) bool (True=attend)."""
    b, sq, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk
    q = q.reshape(b, sq, hk, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h * hd)


def _block_mask(q0, k0, cq: int, ckv: int, window: Optional[int],
                prefix_len: Optional[Array]) -> Array:
    """(cq, ckv) mask for a (q-chunk, kv-chunk) block at offsets (q0, k0)."""
    qpos = q0 + jnp.arange(cq)[:, None]
    kpos = k0 + jnp.arange(ckv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    if prefix_len is not None:
        m |= (qpos < prefix_len) & (kpos < prefix_len)
    return m


def _sdpa_chunked(q: Array, k: Array, v: Array, cfg: ModelConfig,
                  window: Optional[int], prefix_len: Optional[Array],
                  q_chunk: int = 1024, kv_chunk: int = 1024) -> Array:
    """Flash-style attention on the XLA path: online softmax over KV chunks inside a
    scan over Q chunks, with the inner pass rematerialized in the backward pass.
    Never materializes the (S, S) score matrix — this is what keeps the 32k prefill
    dry-run inside HBM. (On real TPU the Pallas kernel replaces this; same contract.)
    """
    b, s, h, hd = q.shape
    hk = k.shape[2]
    g = h // hk

    def pick(target):
        # largest power-of-two chunk <= target that divides s (handles odd lengths
        # like 32768 + a 256-patch VLM prefix)
        c = min(target, s)
        while c > 1 and s % c:
            c //= 2
        return max(c, 1)

    cq, ckv = pick(q_chunk), pick(kv_chunk)
    nq, nkv = s // cq, s // ckv

    # (B, K, G, S, D) / (B, K, S, D)
    qt = q.reshape(b, s, hk, g, hd).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def q_block(qi):
        qc = jax.lax.dynamic_slice_in_dim(qt, qi * cq, cq, axis=3)

        def kv_body(carry, kj):
            m_run, l_run, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(kt, kj * ckv, ckv, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vt, kj * ckv, ckv, axis=2)
            s_blk = jnp.einsum("bkgqd,bktd->bkgqt", qc, kc).astype(jnp.float32)
            s_blk = s_blk * scale
            mask = _block_mask(qi * cq, kj * ckv, cq, ckv, window, prefix_len)
            s_blk = jnp.where(mask, s_blk, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s_blk, axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] \
                + jnp.einsum("bkgqt,bktd->bkgqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hk, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hk, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hk, g, cq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                          jnp.arange(nkv, dtype=jnp.int32))
        return acc / jnp.maximum(l_f, 1e-30)[..., None]

    # scan over q chunks; each chunk's inner pass is rematerialized in bwd
    blocks = jax.lax.map(jax.checkpoint(q_block), jnp.arange(nq, dtype=jnp.int32))
    # blocks: (NQ, B, K, G, CQ, D) -> (B, S, H*D)
    out = blocks.transpose(1, 2, 3, 0, 4, 5).reshape(b, hk, g, s, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h * hd)
    return out.astype(v.dtype)


_CHUNK_THRESHOLD = 2048  # use the chunked path for sequences beyond this


def _sdpa_dispatch(q, k, v, cfg: ModelConfig, window, prefix_len) -> Array:
    s = q.shape[1]
    if s > _CHUNK_THRESHOLD:
        return _sdpa_chunked(q, k, v, cfg, window, prefix_len)
    return _sdpa(q, k, v, causal_mask(s, s, window, prefix_len), cfg)


def causal_mask(sq: int, skv: int, window: Optional[int] = None,
                prefix_len: Optional[Array] = None) -> Array:
    """(1, sq, skv) causal (optionally sliding-window) mask; sq positions are the
    last sq of skv. ``prefix_len`` enables bidirectional attention within the first
    ``prefix_len`` positions (prefix-LM, e.g. PaliGemma's image prefix)."""
    qpos = jnp.arange(sq)[:, None] + (skv - sq)
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    if prefix_len is not None:
        m |= (qpos < prefix_len) & (kpos < prefix_len)
    return m[None]


def attention(params, x: Array, cfg: ModelConfig, window: Optional[int] = None,
              prefix_len: Optional[Array] = None) -> Array:
    """Full-sequence (train) attention."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(params, x, cfg, positions)
    out = _sdpa_dispatch(q, k, v, cfg, window, prefix_len)
    return out @ params["wo"]


def attention_prefill(params, x: Array, cfg: ModelConfig, cache: dict,
                      window: Optional[int] = None,
                      prefix_len: Optional[Array] = None):
    """Full-sequence pass that also fills the decode cache with k/v.

    For sliding-window layers the cache is a ring buffer of size window; we store
    the last ``window`` positions at their ring slots."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(params, x, cfg, positions)
    out = _sdpa_dispatch(q, k, v, cfg, window, prefix_len)
    s_max = cache["k"].shape[1]
    if window is not None and s > s_max:
        # keep only the last s_max positions, placed at their ring-buffer slots
        slots = (jnp.arange(s - s_max, s)) % s_max
        ck = cache["k"].at[:, slots].set(k[:, -s_max:])
        cv = cache["v"].at[:, slots].set(v[:, -s_max:])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k[:, :s_max], (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v[:, :s_max], (0, 0, 0, 0))
    return out @ params["wo"], {"k": ck, "v": cv}


def attention_decode(params, x: Array, cfg: ModelConfig, cache: dict, pos: Array,
                     window: Optional[int] = None):
    """One-token decode. cache: {'k','v': (B, S_max, Hkv, D)}; ``pos`` is the
    current index — () for a lockstep batch, or (B,) when each batch row sits at
    its own depth (the serving engine's continuous-batching slots).

    Returns (out, new_cache). The cache is a ring buffer when ``window`` is set
    (bounded memory for sliding-window layers)."""
    b = x.shape[0]
    pos = jnp.asarray(pos)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.broadcast_to(pos, (b, 1))
    q, k, v = _qkv(params, x, cfg, positions)
    s_max = cache["k"].shape[1]
    slot = pos % s_max if window is not None else pos
    if per_slot:
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, slot].set(k[:, 0])
        cv = cache["v"].at[rows, slot].set(v[:, 0])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    kpos = jnp.arange(s_max)[None, :]
    qref = pos[:, None] if per_slot else pos          # (B, 1) rows or () scalar
    sref = slot[:, None] if per_slot else slot
    if window is not None:
        # ring buffer: valid slots are the last min(pos+1, s_max) written
        age = (sref - kpos) % s_max
        mask = age < jnp.minimum(qref + 1, s_max)
    else:
        mask = kpos <= qref
    out = _sdpa(q, ck, cv, mask[:, None, :], cfg)     # (B|1, 1, S_max) mask
    return out @ params["wo"], {"k": ck, "v": cv}


def init_attention_cache(cfg: ModelConfig, batch: int, s_max: int, dtype) -> dict:
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, s_max, hk, hd), dtype),
            "v": jnp.zeros((batch, s_max, hk, hd), dtype)}


# ---------------------------------------------------------------------------
# paged attention (block-table KV cache — the serving engine's memory plane)
# ---------------------------------------------------------------------------
def init_attention_cache_paged(cfg: ModelConfig, num_pages: int, page_size: int,
                               dtype) -> dict:
    """Physical page pool for one attention layer: K/V as (P, page, Hkv, D).
    Page 0 is the null/trash page (see ``serve.paging``)."""
    hk, hd = cfg.num_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((num_pages, page_size, hk, hd), dtype),
            "v": jnp.zeros((num_pages, page_size, hk, hd), dtype)}


def _gather_pages(pages: Array, table: Array) -> Array:
    """pages: (P, page, Hkv, D); table: (..., maxp) -> (..., maxp*page, Hkv, D).

    The gathered sequence is the slot's cache in logical order, padded by null
    pages to exactly maxp*page positions — when maxp*page == max_cache this is
    the same K/V tensor (values *and* shape) the dense slot-row layout holds,
    so the masked softmax downstream is bitwise identical to the unpaged path.
    """
    g = pages[table]                                   # (..., maxp, page, Hk, D)
    return g.reshape(*table.shape[:-1], -1, *pages.shape[2:])


def attention_decode_paged(params, x: Array, cfg: ModelConfig, cache: dict,
                           pos: Array, table: Array, active: Array,
                           backend: str = "xla"):
    """One-token decode against a paged KV cache.

    cache: {'k','v': (P, page, Hkv, D)} physical page pools; ``pos`` (B,) is each
    slot's cache position; ``table`` (B, maxp) the block table; ``active`` (B,)
    routes the writes of inactive slots to the null page so a garbage lane can
    never dirty a page a mid-prefill slot already owns.

    ``backend`` picks the attention compute: ``"xla"`` gathers the pages into a
    dense (B, maxp*page, ...) K/V and runs the masked-softmax oracle (bitwise
    the dense slot-row path); ``"pallas"`` / ``"pallas_interpret"`` run the
    ``kernels.paged_attention`` scalar-prefetch kernel instead — the block
    table becomes the DMA schedule and no contiguous K/V tensor ever exists.
    Writes are identical either way, so the backends can be swapped mid-stream.
    """
    b = x.shape[0]
    pos = jnp.asarray(pos)
    q, k, v = _qkv(params, x, cfg, pos[:, None])
    page = cache["k"].shape[1]
    rows = jnp.arange(b)
    pidx = jnp.where(active, table[rows, pos // page], 0)
    off = pos % page
    ck = cache["k"].at[pidx, off].set(k[:, 0])
    cv = cache["v"].at[pidx, off].set(v[:, 0])
    if backend != "xla":
        # deferred import: layers must stay importable without the kernel pkg
        from ..kernels.paged_attention import paged_attention as paged_kernel
        interpret = True if backend == "pallas_interpret" else None
        out = paged_kernel(q[:, 0], ck, cv, table,
                           (pos + 1).astype(jnp.int32), interpret=interpret)
        out = out.reshape(b, 1, -1).astype(v.dtype)
    else:
        gk = _gather_pages(ck, table)                  # (B, maxp*page, Hk, D)
        gv = _gather_pages(cv, table)
        kpos = jnp.arange(gk.shape[1])[None, :]
        mask = kpos <= pos[:, None]                    # (B, S)
        out = _sdpa(q, gk, gv, mask[:, None, :], cfg)
    return out @ params["wo"], {"k": ck, "v": cv}


def attention_verify_paged(params, x: Array, cfg: ModelConfig, cache: dict,
                           pos: Array, table: Array, active: Array,
                           backend: str = "xla"):
    """Batched k-position verify step against the paged KV cache
    (self-speculative decoding).

    x: (B, Sq, D) — row 0 is the slot's last emitted token at cache position
    ``pos`` (exactly what the next decode tick would feed), rows 1..Sq-1 the
    draft tokens at ``pos+1..pos+Sq-1``. Every row writes its K/V at its own
    position (inactive slots and positions past the block table route to the
    null page) and attends causally up to itself — per-row this is bitwise
    the computation ``attention_decode_paged`` would run at that position
    with that K/V prefix resident, which is the whole accept-oracle argument.
    Positions the accept loop rejects hold draft K/V afterwards; they are
    only ever read masked and are overwritten by the next tick's writes
    before becoming visible.
    """
    b, sq, _ = x.shape
    pos = jnp.asarray(pos)
    lpos = pos[:, None] + jnp.arange(sq)[None, :]       # (B, Sq) absolute
    q, k, v = _qkv(params, x, cfg, lpos)
    page = cache["k"].shape[1]
    maxp = table.shape[1]
    # writes: each row lands at its own position; inactive lanes and rows
    # past the table's capacity go to the null/trash page
    writable = active[:, None] & (lpos < maxp * page)
    pidx = jnp.take_along_axis(table, jnp.clip(lpos // page, 0, maxp - 1),
                               axis=1)
    pidx = jnp.where(writable, pidx, 0)
    off = lpos % page
    ck = cache["k"].at[pidx, off].set(k)
    cv = cache["v"].at[pidx, off].set(v)
    if backend != "xla":
        # deferred import: layers must stay importable without the kernel pkg
        from ..kernels.paged_attention import paged_attention_verify
        interpret = True if backend == "pallas_interpret" else None
        out = paged_attention_verify(q, ck, cv, table,
                                     pos.astype(jnp.int32),
                                     interpret=interpret)
        out = out.reshape(b, sq, -1).astype(v.dtype)
    else:
        gk = _gather_pages(ck, table)                  # (B, maxp*page, Hk, D)
        gv = _gather_pages(cv, table)
        kpos = jnp.arange(gk.shape[1])[None, None, :]
        mask = kpos <= lpos[:, :, None]                # (B, Sq, S)
        out = _sdpa(q, gk, gv, mask, cfg)
    return out @ params["wo"], {"k": ck, "v": cv}


def attention_prefill_paged(params, x: Array, cfg: ModelConfig, cache: dict,
                            table_row: Array, p0: Array):
    """One prefill *chunk* (batch-of-1) written straight into the slot's pages.

    x: (1, C, D) — the chunk's embeddings; ``table_row`` (maxp,) the slot's
    block table row; ``p0`` the chunk's first absolute position. Queries attend
    over the gathered pages (fixed maxp*page == max_cache length), so every
    chunk call compiles one shape regardless of prompt length — and, because
    padded/garbage positions are masked to exact zeros, the result is bitwise
    identical to the one-shot full-sequence prefill.
    """
    _, c, _ = x.shape
    lpos = p0 + jnp.arange(c)                          # absolute positions
    q, k, v = _qkv(params, x, cfg, lpos[None, :])
    page = cache["k"].shape[1]
    ck = cache["k"].at[table_row[lpos // page], lpos % page].set(k[0])
    cv = cache["v"].at[table_row[lpos // page], lpos % page].set(v[0])
    gk = _gather_pages(ck, table_row)[None]            # (1, maxp*page, Hk, D)
    gv = _gather_pages(cv, table_row)[None]
    kpos = jnp.arange(gk.shape[1])[None, :]
    mask = (kpos <= lpos[:, None])[None]               # (1, C, S)
    out = _sdpa(q, gk, gv, mask, cfg)
    return out @ params["wo"], {"k": ck, "v": cv}


def attention_prefill_paged_multi(params, x: Array, cfg: ModelConfig,
                                  cache: dict, tables: Array, p0s: Array):
    """``J`` concurrent prefill chunks, one per lane, in a single call.

    x: (J, C, D) — each lane is one in-flight chunked-prefill job's chunk;
    ``tables`` (J, maxp) each lane's block-table row; ``p0s`` (J,) each chunk's
    first absolute position. Lanes write into disjoint page sets (the allocator
    guarantees a writable page has exactly one owner), except padding lanes,
    whose all-null tables route every write to the null/trash page. Each lane's
    math is row-independent and shape-identical to the single-job path, so
    batching jobs costs no exactness — it just turns J prefill dispatches per
    tick into one.
    """
    j, c, _ = x.shape
    lpos = p0s[:, None] + jnp.arange(c)[None, :]       # (J, C) absolute
    q, k, v = _qkv(params, x, cfg, lpos)
    page = cache["k"].shape[1]
    pidx = jnp.take_along_axis(tables, lpos // page, axis=1)   # (J, C)
    off = lpos % page
    ck = cache["k"].at[pidx, off].set(k)
    cv = cache["v"].at[pidx, off].set(v)
    gk = _gather_pages(ck, tables)                     # (J, maxp*page, Hk, D)
    gv = _gather_pages(cv, tables)
    kpos = jnp.arange(gk.shape[1])[None, None, :]
    mask = kpos <= lpos[:, :, None]                    # (J, C, S)
    out = _sdpa(q, gk, gv, mask, cfg)
    return out @ params["wo"], {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    dt = dtype_of(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": dense_init(k1, d, f, dt),
        "w_up": dense_init(k2, d, f, dt),
        "w_down": dense_init(k3, f, d, dt),
    }


def mlp(params, x: Array, cfg: ModelConfig) -> Array:
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    h = constrain(act(x @ params["w_gate"]) * (x @ params["w_up"]),
                  DP, None, "model")
    return h @ params["w_down"]
