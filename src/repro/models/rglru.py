"""RecurrentGemma / Griffin recurrent block (arXiv:2402.19427): RG-LRU + conv.

Train/prefill evaluates the linear recurrence with an associative scan (log-depth on
TPU); decode carries (B, lru_width) state — O(1) per token, so the hybrid serves
``long_500k`` with bounded memory (its attention layers are sliding-window).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import dense_init, dtype_of

Array = jax.Array

_C = 8.0  # the paper's fixed recurrence sharpness


def init_rglru(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    w = cfg.lru_width or cfg.d_model
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    # Lambda init so a = exp(-c*softplus(L)*r) lands in a useful decay range
    lam = jax.random.uniform(k6, (w,), jnp.float32, 0.2, 0.9)
    return {
        "w_in": dense_init(k1, cfg.d_model, w, dt),
        "w_gate_branch": dense_init(k2, cfg.d_model, w, dt),
        "conv_w": (jax.random.normal(k3, (4, w), jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_input_gate": dense_init(k4, w, w, dt),
        "w_rec_gate": dense_init(k5, w, w, dt),
        "lambda_raw": jnp.log(jnp.exp(lam) - 1.0),     # inverse softplus
        "w_out": dense_init(jax.random.fold_in(key, 7), w, cfg.d_model, dt),
    }


def _conv4(params, u: Array) -> Array:
    w = params["conv_w"]
    out = u * w[-1]
    for i in range(1, w.shape[0]):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, :u.shape[1]]
        out = out + shifted * w[-1 - i]
    return out + params["conv_b"].astype(out.dtype)


def _gates(params, u: Array):
    """a_t (log-space) and gated input b_t for the recurrence h = a h + b."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_rec_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ params["w_input_gate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lambda_raw"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) input normalization (the RG-LRU's variance preservation)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rglru_scan(a: Array, b: Array) -> Array:
    """h_t = a_t * h_{t-1} + b_t along axis 1 via associative scan."""

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence Griffin recurrent block. x: (B,S,D) -> (B,S,D)."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u = _conv4(params, x @ params["w_in"])
    a, b = _gates(params, u)
    h = rglru_scan(a, b).astype(x.dtype)
    return (h * gate) @ params["w_out"]


def rglru_block_prefill(params, x: Array, cfg: ModelConfig, cache: dict):
    """Full-sequence pass that also produces the decode cache."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u_pre = x @ params["w_in"]
    u = _conv4(params, u_pre)
    a, b = _gates(params, u)
    h = rglru_scan(a, b)
    y = (h.astype(x.dtype) * gate) @ params["w_out"]
    k = 3
    conv_tail = u_pre[:, -k:] if u_pre.shape[1] >= k else jnp.pad(
        u_pre, ((0, 0), (k - u_pre.shape[1], 0), (0, 0)))
    return y, {"conv": conv_tail, "h": h[:, -1]}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, 3, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_block_decode(params, x: Array, cfg: ModelConfig, cache: dict):
    """One-token step. x: (B,1,D)."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])    # (B,1,W)
    u_new = (x @ params["w_in"])                        # (B,1,W)
    window = jnp.concatenate([cache["conv"], u_new], axis=1)   # (B,4,W)
    u = jnp.einsum("bkw,kw->bw", window, params["conv_w"]) + params["conv_b"]
    a, b = _gates(params, u[:, None, :])
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = (h[:, None, :].astype(x.dtype) * gate) @ params["w_out"]
    return y, {"conv": window[:, 1:], "h": h}
