"""Top-level model API: init / train loss / prefill / decode, uniform across families.

Batch layouts (all fields optional per family):
    train:   {"tokens": (B,S) i32, "patches": (B,P,Fd), "frames": (B,S,Fd)}
    decode:  {"token": (B,1) i32, "frame": (B,1,Fd)} + cache + pos

Losses are next-token cross-entropy in fp32; for VLM the loss is masked to the text
positions (the patch prefix carries no labels).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import frontends, layers, transformer
from .layers import dtype_of, embed_init, init_rmsnorm, rmsnorm

Array = jax.Array


class TrainOut(NamedTuple):
    loss: Array
    aux_loss: Array                # Switch aux loss (0 unless router_mode == 'aux')
    load_frac: Optional[Array]     # (L, E) per-layer expert load fractions
    drop_frac: Array
    logits_mean_abs: Array         # cheap NaN/scale canary


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype_of(cfg)),
        "stack": transformer.init_stack(ks[1], cfg),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype_of(cfg))
    if cfg.frontend_dim:
        p["frontend"] = frontends.init_frontend(ks[3], cfg)
    return p


def _embed(params, cfg: ModelConfig, tokens: Array) -> Array:
    # gather the (possibly fsdp-sharded) table at use, keep activations on DP
    table = layers.constrain(params["embed"], "model", None)
    x = table[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    return layers.constrain(x, layers.DP, None, None)


def _head(params, cfg: ModelConfig, x: Array) -> Array:
    x = rmsnorm(params["final_norm"], x, cfg)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    table = layers.constrain(table, "model", None)
    logits = (x @ table.T).astype(jnp.float32)
    return layers.constrain(logits, layers.DP, None, "model")


def _inputs_train(params, cfg: ModelConfig, batch: dict):
    """Returns (x, prefix_len, label_mask_offset)."""
    if cfg.family == "vlm":
        prefix = frontends.project_frontend(params["frontend"], batch["patches"])
        text = _embed(params, cfg, batch["tokens"])
        x = jnp.concatenate([prefix, text], axis=1)
        return x, prefix.shape[1]
    if cfg.family == "audio":
        # EnCodec frame embeddings (stub frontend) + code-token embeddings
        x = _embed(params, cfg, batch["tokens"]) \
            + frontends.project_frontend(params["frontend"], batch["frames"])
        return x, 0
    return _embed(params, cfg, batch["tokens"]), 0


def train_loss(params, cfg: ModelConfig, batch: dict,
               router_bias: Optional[Array] = None) -> TrainOut:
    tokens = batch["tokens"]
    x, plen = _inputs_train(params, cfg, batch)
    prefix_len = jnp.asarray(plen) if plen else None
    x, load, aux, drop = transformer.apply_stack(params["stack"], x, cfg,
                                                 bias=router_bias,
                                                 prefix_len=prefix_len)
    x = x[:, plen:] if plen else x
    logits = _head(params, cfg, x)                      # (B, S, V) fp32

    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    # vocab-sharded-friendly CE: logsumexp + one-hot contraction (no gather over the
    # TP-sharded vocab dim — a take_along_axis would all-gather the full logits)
    lse = jax.nn.logsumexp(lg, axis=-1)
    correct = jnp.sum(lg * jax.nn.one_hot(targets, lg.shape[-1], dtype=lg.dtype),
                      axis=-1)
    loss = jnp.mean(lse - correct)
    if cfg.router_mode == "aux" and cfg.num_experts:
        loss = loss + cfg.aux_loss_coef * aux
    return TrainOut(loss=loss, aux_loss=aux, load_frac=load, drop_frac=drop,
                    logits_mean_abs=jnp.mean(jnp.abs(lg)))


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    return {
        "layers": transformer.init_stack_cache(cfg, batch, s_max, dtype_of(cfg)),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict,
            router_bias: Optional[Array] = None):
    """Batched prompt processing; fills the cache and returns last-position logits."""
    x, plen = _inputs_train(params, cfg, batch)
    prefix_len = jnp.asarray(plen) if plen else None
    x, layer_caches = transformer.apply_stack_prefill(
        params["stack"], x, cfg, cache["layers"], bias=router_bias,
        prefix_len=prefix_len)
    logits = _head(params, cfg, x[:, -1:])
    new_cache = {"layers": layer_caches,
                 "pos": jnp.asarray(x.shape[1], jnp.int32)}
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, batch: dict, cache: dict,
                router_bias: Optional[Array] = None):
    """One-token step for every sequence in the batch. Returns (logits, new_cache)."""
    x = _embed(params, cfg, batch["token"])
    if cfg.family == "audio":
        x = x + frontends.project_frontend(params["frontend"], batch["frame"])
    x, layer_caches = transformer.apply_stack_decode(
        params["stack"], x, cfg, cache["layers"], cache["pos"], bias=router_bias)
    logits = _head(params, cfg, x)
    return logits, {"layers": layer_caches, "pos": cache["pos"] + 1}


# ---------------------------------------------------------------------------
# slot-pool cache surgery (continuous-batching serving engine)
# ---------------------------------------------------------------------------
def init_slot_cache(cfg: ModelConfig, num_slots: int, s_max: int) -> dict:
    """Pooled decode cache for the serving engine: like ``init_cache`` but with a
    per-slot (num_slots,) position vector, so slots can sit at different depths
    of their own sequences while sharing one compiled decode step."""
    cache = init_cache(cfg, num_slots, s_max)
    return {"layers": cache["layers"],
            "pos": jnp.zeros((num_slots,), jnp.int32)}


def insert_slot_cache(pool: dict, one: dict, slot: Array) -> dict:
    """Splice a freshly prefilled batch-of-1 cache into ``slot`` of a pooled
    cache (prefill-into-slot). Layer-cache leaves are stacked (depth, batch, ...)
    so the batch axis is axis 1; the whole slot row is overwritten, which also
    erases any stale state from the slot's previous occupant."""
    layer_caches = jax.tree.map(
        lambda full, o: jax.lax.dynamic_update_slice_in_dim(
            full, o.astype(full.dtype), slot, axis=1),
        pool["layers"], one["layers"])
    return {"layers": layer_caches,
            "pos": pool["pos"].at[slot].set(one["pos"].astype(pool["pos"].dtype))}


def reset_slot_cache(pool: dict, slot: Array) -> dict:
    """Retire a slot: zero its cache row and position (compaction for reuse)."""
    layer_caches = jax.tree.map(lambda full: full.at[:, slot].set(0),
                                pool["layers"])
    return {"layers": layer_caches, "pos": pool["pos"].at[slot].set(0)}


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE counts only k of E experts)."""
    total = param_count(params)
    if not cfg.num_experts:
        return total

    def expert_leaves(p):
        return sum(int(x.size) for name in ("w_gate", "w_up", "w_down")
                   for x in jax.tree.leaves(p.get(name, ())))

    expert_total = 0
    for seg in params["stack"]:
        for pos_params in seg:
            if isinstance(pos_params, dict) and "moe" in pos_params:
                expert_total += expert_leaves(pos_params["moe"])
    active_frac = cfg.experts_per_token / cfg.num_experts
    return int(total - expert_total * (1.0 - active_frac))
