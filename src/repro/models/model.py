"""Top-level model API: init / train loss / prefill / decode, uniform across families.

Batch layouts (all fields optional per family):
    train:   {"tokens": (B,S) i32, "patches": (B,P,Fd), "frames": (B,S,Fd)}
    decode:  {"token": (B,1) i32, "frame": (B,1,Fd)} + cache + pos

Losses are next-token cross-entropy in fp32; for VLM the loss is masked to the text
positions (the patch prefix carries no labels).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import frontends, layers, transformer
from .layers import dtype_of, embed_init, init_rmsnorm, rmsnorm

Array = jax.Array


class TrainOut(NamedTuple):
    loss: Array
    aux_loss: Array                # Switch aux loss (0 unless router_mode == 'aux')
    load_frac: Optional[Array]     # (L, E) per-layer expert load fractions
    drop_frac: Array
    logits_mean_abs: Array         # cheap NaN/scale canary


class SamplingSpec(NamedTuple):
    """Per-lane sampling arrays for a batch of decode lanes (jit-friendly).

    One row per lane — a slot of the serving engine's pool or a sequence of a
    one-shot batch. Both backends feed the same rows through the same
    ``sample_tokens`` lane, which is what makes engine-vs-oneshot token parity
    hold bitwise for seeded sampling."""

    keys: Array         # (B, 2) uint32 per-lane base PRNG keys
    temperature: Array  # (B,) f32; 0 => exact argmax (the greedy lane)
    top_k: Array        # (B,) i32; 0 => disabled
    top_p: Array        # (B,) f32; 1.0 => disabled
    rep_penalty: Array  # (B,) f32; 1.0 => disabled (repetition penalty)
    pres_penalty: Array  # (B,) f32; 0.0 => disabled (presence penalty)
    freq_penalty: Array  # (B,) f32; 0.0 => disabled (frequency penalty)


def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype_of(cfg)),
        "stack": transformer.init_stack(ks[1], cfg),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype_of(cfg))
    if cfg.frontend_dim:
        p["frontend"] = frontends.init_frontend(ks[3], cfg)
    return p


def _embed(params, cfg: ModelConfig, tokens: Array) -> Array:
    # gather the (possibly fsdp-sharded) table at use, keep activations on DP
    table = layers.constrain(params["embed"], "model", None)
    x = table[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
    return layers.constrain(x, layers.DP, None, None)


def _head(params, cfg: ModelConfig, x: Array) -> Array:
    x = rmsnorm(params["final_norm"], x, cfg)
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    table = layers.constrain(table, "model", None)
    logits = (x @ table.T).astype(jnp.float32)
    return layers.constrain(logits, layers.DP, None, "model")


def _inputs_train(params, cfg: ModelConfig, batch: dict):
    """Returns (x, prefix_len, label_mask_offset)."""
    if cfg.family == "vlm":
        prefix = frontends.project_frontend(params["frontend"], batch["patches"])
        text = _embed(params, cfg, batch["tokens"])
        x = jnp.concatenate([prefix, text], axis=1)
        return x, prefix.shape[1]
    if cfg.family == "audio":
        # EnCodec frame embeddings (stub frontend) + code-token embeddings
        x = _embed(params, cfg, batch["tokens"]) \
            + frontends.project_frontend(params["frontend"], batch["frames"])
        return x, 0
    return _embed(params, cfg, batch["tokens"]), 0


def train_loss(params, cfg: ModelConfig, batch: dict,
               router_bias: Optional[Array] = None) -> TrainOut:
    tokens = batch["tokens"]
    x, plen = _inputs_train(params, cfg, batch)
    prefix_len = jnp.asarray(plen) if plen else None
    x, load, aux, drop = transformer.apply_stack(params["stack"], x, cfg,
                                                 bias=router_bias,
                                                 prefix_len=prefix_len)
    x = x[:, plen:] if plen else x
    logits = _head(params, cfg, x)                      # (B, S, V) fp32

    targets = tokens[:, 1:]
    lg = logits[:, :-1]
    # vocab-sharded-friendly CE: logsumexp + one-hot contraction (no gather over the
    # TP-sharded vocab dim — a take_along_axis would all-gather the full logits)
    lse = jax.nn.logsumexp(lg, axis=-1)
    correct = jnp.sum(lg * jax.nn.one_hot(targets, lg.shape[-1], dtype=lg.dtype),
                      axis=-1)
    loss = jnp.mean(lse - correct)
    if cfg.router_mode == "aux" and cfg.num_experts:
        loss = loss + cfg.aux_loss_coef * aux
    return TrainOut(loss=loss, aux_loss=aux, load_frac=load, drop_frac=drop,
                    logits_mean_abs=jnp.mean(jnp.abs(lg)))


def penalize_logits(lg: Array, spec: SamplingSpec, counts: Array) -> Array:
    """Apply per-lane repetition / presence / frequency penalties to (B, V)
    logits given ``counts`` (B, V) — how often each vocab id has been
    *generated* by the lane's request so far (prompt tokens are not counted;
    the seed token is, once emitted). Lanes with all three penalties at their
    neutral values (1.0 / 0.0 / 0.0) are returned **bitwise unchanged** via a
    per-lane ``where`` — the penalty-free path cannot drift by construction,
    which is what keeps the parity oracle's greedy claims intact."""
    cnt = counts.astype(jnp.float32)
    counted = cnt > 0
    rep = spec.rep_penalty[:, None]
    scaled = jnp.where(lg > 0, lg / rep, lg * rep)
    pen = jnp.where(counted, scaled, lg) \
        - spec.freq_penalty[:, None] * cnt \
        - spec.pres_penalty[:, None] * counted.astype(jnp.float32)
    neutral = ((spec.rep_penalty == 1.0) & (spec.pres_penalty == 0.0)
               & (spec.freq_penalty == 0.0))
    return jnp.where(neutral[:, None], lg, pen)


def sample_tokens(logits: Array, spec: SamplingSpec, step,
                  counts: Optional[Array] = None) -> Array:
    """Sample one token per lane from last-position ``logits`` ((B, V) or
    (B, T, V), last position used) under per-lane ``SamplingSpec`` rows.

    The per-step key is ``fold_in(lane key, step)`` where ``step`` is the
    index of the token being emitted (scalar or (B,) — the engine passes each
    slot's emitted-token count, the one-shot loop its scan index), so a
    request's key stream is a function of its params and its own progress
    only, never of what shares the batch. Each lane is row-wise — scale by
    temperature, full descending sort, top-k rank mask, top-p cumulative-mass
    mask (the top token always survives), Gumbel draw over the survivors — so
    a lane's token is bitwise independent of batch composition; temperature-0
    lanes short out to the exact ``argmax`` the greedy path takes.

    ``counts`` (B, V) switches on the repetition/presence/frequency penalty
    lane (:func:`penalize_logits`) ahead of both the greedy argmax and the
    sampled draw; penalty-free lanes stay bitwise on the unpenalized path."""
    lg = logits[:, -1] if logits.ndim == 3 else logits          # (B, V) fp32
    if counts is not None:
        lg = penalize_logits(lg, spec, counts)
    b, v = lg.shape
    greedy_tok = jnp.argmax(lg, axis=-1)
    keys = jax.vmap(jax.random.fold_in)(
        spec.keys, jnp.broadcast_to(jnp.asarray(step, jnp.int32), (b,)))

    def lane(row, key, temp, k, p):
        scaled = row / jnp.maximum(temp, jnp.float32(1e-6))
        vals, idx = jax.lax.top_k(scaled, v)                    # full sort
        keep_k = jnp.arange(v) < jnp.where(k > 0, k, v)
        probs = jax.nn.softmax(vals)
        mass_before = jnp.cumsum(probs) - probs
        masked = jnp.where(keep_k & (mass_before < p), vals, -jnp.inf)
        return idx[jax.random.categorical(key, masked)]

    sampled = jax.vmap(lane)(lg, keys, spec.temperature, spec.top_k,
                             spec.top_p)
    tok = jnp.where(spec.temperature > 0, sampled, greedy_tok)
    return tok[:, None].astype(jnp.int32)                       # (B, 1)


def chosen_logprob(logits: Array, tok: Array) -> Array:
    """Logprob of each lane's chosen token under the *raw* model distribution
    (log-softmax of the unscaled fp32 logits — independent of temperature /
    top-k / top-p, so greedy and sampled lanes report on the same scale).
    ``logits`` (B, V) or (B, T, V) (last position used), ``tok`` (B, 1);
    returns (B, 1) fp32. Pure row-wise math on the logits lane both backends
    already hold, so the value is bitwise identical engine-vs-oneshot wherever
    the logits are."""
    lg = logits[:, -1] if logits.ndim == 3 else logits          # (B, V) fp32
    lp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, tok.astype(jnp.int32), axis=-1)


def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    return {
        "layers": transformer.init_stack_cache(cfg, batch, s_max, dtype_of(cfg)),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict,
            router_bias: Optional[Array] = None):
    """Batched prompt processing; fills the cache and returns last-position logits."""
    x, plen = _inputs_train(params, cfg, batch)
    prefix_len = jnp.asarray(plen) if plen else None
    x, layer_caches = transformer.apply_stack_prefill(
        params["stack"], x, cfg, cache["layers"], bias=router_bias,
        prefix_len=prefix_len)
    logits = _head(params, cfg, x[:, -1:])
    new_cache = {"layers": layer_caches,
                 "pos": jnp.asarray(x.shape[1], jnp.int32)}
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, batch: dict, cache: dict,
                router_bias: Optional[Array] = None,
                table: Optional[Array] = None,
                active: Optional[Array] = None,
                attn_backend: str = "xla"):
    """One-token step for every sequence in the batch. Returns (logits, new_cache).

    ``table`` (B, maxp) switches full-attention layers onto the paged KV pool.
    ``active`` (B,) additionally freezes the *slot-row* caches (recurrent
    state, ring buffers) of inactive slots: a garbage lane must never advance
    state a chunked prefill is threading through that row between ticks. The
    paged leaves don't need the freeze — inactive writes are routed to the
    null page inside ``attention_decode_paged``. ``attn_backend`` picks the
    paged attention compute: ``"xla"`` (dense gather oracle) or
    ``"pallas"`` / ``"pallas_interpret"`` (the block-table Pallas kernel)."""
    x = _embed(params, cfg, batch["token"])
    if cfg.family == "audio":
        x = x + frontends.project_frontend(params["frontend"], batch["frame"])
    x, layer_caches = transformer.apply_stack_decode(
        params["stack"], x, cfg, cache["layers"], cache["pos"], bias=router_bias,
        table=table, active=active, attn_backend=attn_backend)
    if active is not None:
        def freeze(kind, new, old):
            if kind in ("attn", "moe"):
                return new
            return jax.tree.map(
                lambda n, o: jnp.where(
                    active.reshape((1, -1) + (1,) * (n.ndim - 2)), n, o),
                new, old)
        layer_caches = transformer.map_block_caches(cfg, freeze, layer_caches,
                                                    cache["layers"])
    logits = _head(params, cfg, x)
    return logits, {"layers": layer_caches, "pos": cache["pos"] + 1}


def verify_step(params, cfg: ModelConfig, batch: dict, pool: dict,
                table: Array, active: Optional[Array] = None,
                attn_backend: str = "xla",
                router_bias: Optional[Array] = None):
    """Batched k-position verify step for self-speculative decoding.

    ``batch["tokens"]`` is (B, K+1): each slot's last emitted token followed by
    its K draft proposals. Row ``j`` is scored at cache position
    ``pool["pos"] + j`` with causal access up to itself — one forward pass
    whose per-row logits are bitwise what ``decode_step`` would produce row by
    row (same gather + ``_sdpa`` contraction per query row, dropless MoE).
    K/V for every row is written into the paged pool as a side effect, so an
    accepted prefix's cache is exactly what sequential decode would have left.

    Returns ``(logits (B, K+1, V), new_pool)``; ``pos`` is left untouched —
    the engine owns position advancement from its host-side accept loop."""
    x = _embed(params, cfg, batch["tokens"])
    x, layer_caches = transformer.apply_stack_verify(
        params["stack"], x, cfg, pool["layers"], pool["pos"],
        bias=router_bias, table=table, active=active,
        attn_backend=attn_backend)
    logits = _head(params, cfg, x)
    return logits, {"layers": layer_caches, "pos": pool["pos"]}


# ---------------------------------------------------------------------------
# paged slot-pool surgery (block-table KV cache, continuous-batching engine)
# ---------------------------------------------------------------------------
def init_slot_cache_paged(cfg: ModelConfig, num_slots: int, s_max: int,
                          num_pages: int, page_size: int) -> dict:
    """Paged pooled decode cache: full-attention K/V live in per-layer physical
    page pools (num_pages, page_size, Hkv, D) indexed by a host-side block
    table; recurrent/ring leaves stay slot-indexed. ``pos`` is per-slot — like
    ``init_cache`` but a (num_slots,) vector, so slots can sit at different
    depths of their own sequences while sharing one compiled decode step. (The
    pre-paging fixed-row layout is the degenerate page_size == s_max config.)"""
    return {"layers": transformer.init_stack_cache_paged(
                cfg, num_slots, s_max, num_pages, page_size, dtype_of(cfg)),
            "pos": jnp.zeros((num_slots,), jnp.int32)}


def insert_slot_cache_paged(pool: dict, one: dict, cfg: ModelConfig,
                            slot: Array, table_row: Array) -> dict:
    """Splice a one-shot prefilled batch-of-1 dense cache into the paged pool.

    Full-attention leaves: the dense (1, s_max, ...) row is reshaped to
    (maxp, page, ...) and scattered to the slot's physical pages; rows beyond
    the slot's allocation land on the null page (table entries there point at
    it), which is by construction write-don't-care. Other leaves are whole-row
    copies at the slot's batch index, erasing any stale state from the slot's
    previous occupant."""
    def splice(kind, full_d, one_d):
        if kind in ("attn", "moe"):
            def pagewise(full, o):
                reps, page = full.shape[0], full.shape[2]
                chunks = o.astype(full.dtype).reshape(
                    reps, -1, page, *full.shape[3:])
                return full.at[:, table_row].set(chunks)
            return jax.tree.map(pagewise, full_d, one_d)
        return jax.tree.map(
            lambda full, o: jax.lax.dynamic_update_slice_in_dim(
                full, o.astype(full.dtype), slot, axis=1), full_d, one_d)

    layer_caches = transformer.map_block_caches(cfg, splice, pool["layers"],
                                                one["layers"])
    return {"layers": layer_caches,
            "pos": pool["pos"].at[slot].set(one["pos"].astype(pool["pos"].dtype))}


def release_slot_cache_paged(pool: dict, cfg: ModelConfig, slot: Array) -> dict:
    """Retire a slot in the paged pool: zero the slot-row (recurrent/ring)
    leaves and the position; physical pages are NOT zeroed — they just return
    to the host free list, and stale contents are only ever read masked."""
    def wipe(kind, full_d):
        if kind in ("attn", "moe"):
            return full_d
        return jax.tree.map(lambda full: full.at[:, slot].set(0), full_d)

    layer_caches = transformer.map_block_caches(cfg, wipe, pool["layers"])
    return {"layers": layer_caches, "pos": pool["pos"].at[slot].set(0)}


def prefill_chunk(params, cfg: ModelConfig, batch: dict, pool: dict,
                  table_row: Array, p0: Array, last_idx: Array, slot: Array,
                  router_bias: Optional[Array] = None):
    """One chunk of a chunked prefill, written straight into the paged pool.

    ``batch`` holds the chunk's tokens (1, C) (+ frames for audio); ``p0`` is
    the chunk's first absolute position and ``last_idx`` the in-chunk index of
    the prompt's final token (only meaningful on the last chunk — the logits
    returned there seed decoding). The pool's ``pos`` is left untouched; the
    engine activates the slot when the final chunk lands."""
    x = _embed(params, cfg, batch["tokens"])
    if cfg.family == "audio":
        x = x + frontends.project_frontend(params["frontend"], batch["frames"])
    x, layer_caches = transformer.apply_stack_prefill_chunk(
        params["stack"], x, cfg, pool["layers"], table_row, p0, slot,
        bias=router_bias)
    logits = _head(params, cfg,
                   jax.lax.dynamic_slice_in_dim(x, last_idx, 1, axis=1))
    return logits, {"layers": layer_caches, "pos": pool["pos"]}


def prefill_chunk_multi(params, cfg: ModelConfig, batch: dict, pool: dict,
                        tables: Array, p0s: Array, last_idx: Array,
                        router_bias: Optional[Array] = None):
    """J concurrent prefill chunks (one in-flight job per lane) in one call.

    ``batch["tokens"]`` is (J, C); ``tables`` (J, maxp) each lane's block-table
    row; ``p0s`` (J,) each chunk's first absolute position; ``last_idx`` (J,)
    the in-chunk index of each prompt's final token (meaningful on a lane's
    last chunk — the logits there seed its decoding). Attention-stack configs
    only: lanes share no slot-row state, so J jobs cost one dispatch instead
    of J without changing any lane's math. Padding lanes carry an all-null
    table. The pool's ``pos`` is untouched; the engine activates each slot as
    its final chunk lands."""
    x = _embed(params, cfg, batch["tokens"])
    x, layer_caches = transformer.apply_stack_prefill_chunk_multi(
        params["stack"], x, cfg, pool["layers"], tables, p0s, bias=router_bias)
    sel = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)   # (J, 1, d)
    logits = _head(params, cfg, sel)
    return logits, {"layers": layer_caches, "pos": pool["pos"]}


def copy_page_paged(pool: dict, cfg: ModelConfig, src: Array, dst: Array) -> dict:
    """Copy-on-write fork: duplicate physical page ``src`` into ``dst`` across
    every paged (full-attention) layer pool. The caller then redirects the
    forking slot's block table to ``dst`` and overwrites the tail; entries
    beyond the shared prefix carry the donor's stale K/V, which is only ever
    read masked (or overwritten by the fork owner's own writes)."""
    def cp(kind, full_d):
        if kind in ("attn", "moe"):
            return jax.tree.map(lambda full: full.at[:, dst].set(full[:, src]),
                                full_d)
        return full_d

    return {"layers": transformer.map_block_caches(cfg, cp, pool["layers"]),
            "pos": pool["pos"]}


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(params, cfg: ModelConfig) -> int:
    """Per-token active parameters (MoE counts only k of E experts)."""
    total = param_count(params)
    if not cfg.num_experts:
        return total

    def expert_leaves(p):
        return sum(int(x.size) for name in ("w_gate", "w_up", "w_down")
                   for x in jax.tree.leaves(p.get(name, ())))

    expert_total = 0
    for seg in params["stack"]:
        for pos_params in seg:
            if isinstance(pos_params, dict) and "moe" in pos_params:
                expert_total += expert_leaves(pos_params["moe"])
    active_frac = cfg.experts_per_token / cfg.num_experts
    return int(total - expert_total * (1.0 - active_frac))
