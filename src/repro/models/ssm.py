"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Train/prefill uses the chunked dual form (intra-chunk quadratic + inter-chunk
recurrence); decode carries an explicit (B, H, P, N) state — O(1) per token, which is
what makes the ``long_500k`` shape servable. ngroups = 1 (B/C shared across heads),
as in the published 130m config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import DP, constrain, dense_init, dtype_of, init_rmsnorm, rmsnorm

Array = jax.Array


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads, cfg.ssm_state, cfg.ssm_head_dim


def init_ssm(key, cfg: ModelConfig) -> dict:
    dt = dtype_of(cfg)
    d_inner, h, n, _ = dims(cfg)
    conv_dim = d_inner + 2 * n
    k1, k2, k3, k4 = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * n + h    # [z, x, B, C, dt]
    return {
        "in_proj": dense_init(k1, cfg.d_model, in_dim, dt),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_dim), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),        # A = -exp(A_log) = -1 at init
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": dense_init(k4, d_inner, cfg.d_model, dt),
    }


def _split(params, x: Array, cfg: ModelConfig):
    """Project x into (z, xBC, dt). The fused in_proj weight is sliced *before* the
    matmuls: slicing the fused activation instead cuts a 'model'-sharded tensor at
    non-shard-aligned offsets, which GSPMD repairs with collective-permutes every
    layer (observed ~0.5 GiB/step of slivers on mamba2-130m)."""
    d_inner, h, n, _ = dims(cfg)
    w = params["in_proj"]
    conv_dim = d_inner + 2 * n
    z = constrain(x @ w[:, :d_inner], DP, None, "model")
    xbc = constrain(x @ w[:, d_inner:d_inner + conv_dim], DP, None, "model")
    dt_raw = x @ w[:, -h:]                      # (B,S,H): tiny, replicated
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    return z, xbc, dt


def _conv_train(params, xbc: Array) -> Array:
    """Causal depthwise conv over the sequence (width cfg.ssm_conv)."""
    w = params["conv_w"]                              # (K, C)
    k = w.shape[0]
    out = xbc * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :xbc.shape[1]]
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + params["conv_b"].astype(out.dtype))


def _segsum_decay(a: Array) -> Array:
    """a: (..., L, H) per-step log-decay -> (..., H, L, L) lower-tri exp decays:
    out[i, j] = exp(sum_{k=j+1..i} a_k) for j <= i else 0."""
    acs = jnp.cumsum(a, axis=-2)                       # inclusive
    diff = acs[..., :, None, :] - acs[..., None, :, :]  # (..., L, L, H) = acs_i - acs_j
    l = a.shape[-2]
    mask = jnp.tril(jnp.ones((l, l), bool))
    diff = jnp.where(mask[..., None], diff, -jnp.inf)
    return jnp.exp(jnp.moveaxis(diff, -1, -3))         # (..., H, L, L)


def ssd_chunked(x: Array, dt: Array, a_log: Array, b: Array, c: Array,
                chunk: int, return_state: bool = False,
                init_state: Array | None = None):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); b, c: (B,S,N). Returns (B,S,H,P)
    (and the final recurrence state (B,H,N,P) when ``return_state``).

    ``init_state`` resumes the inter-chunk recurrence mid-sequence (chunked
    prefill). The scan body is a single elementwise multiply-add per chunk, so
    splitting a sequence across calls at chunk boundaries reproduces the
    one-shot op order exactly — resumed prefill is bitwise-identical as long as
    every piece is a multiple of ``chunk``."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        x, dt, b, c = (jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2))
                       for v in (x, dt, b, c))
    nc = x.shape[1] // l
    xc = x.reshape(bsz, nc, l, h, p)
    dtc = dt.reshape(bsz, nc, l, h)
    bc = b.reshape(bsz, nc, l, n)
    cc = c.reshape(bsz, nc, l, n)

    a = dtc * (-jnp.exp(a_log))                        # (B,NC,L,H), negative
    xdt = xc * dtc[..., None]
    decay = _segsum_decay(a)                           # (B,NC,H,L,L)

    # intra-chunk (the "attention-like" dual)
    att = jnp.einsum("bcin,bcjn->bcij", cc, bc)
    y = jnp.einsum("bcij,bchij,bcjhp->bcihp", att, decay, xdt)

    # chunk-final states + inter-chunk recurrence
    acs = jnp.cumsum(a, axis=2)
    tail = jnp.exp(acs[:, :, -1:, :] - acs)            # (B,NC,L,H) decay to chunk end
    states = jnp.einsum("bcln,bclh,bclhp->bchnp", bc, tail, xdt)
    chunk_decay = jnp.exp(acs[:, :, -1, :])            # (B,NC,H)

    def step(carry, inp):
        st, dk = inp
        new = carry * dk[..., None, None] + st
        return new, carry                               # emit state *entering* the chunk

    init = (jnp.zeros((bsz, h, n, p), x.dtype) if init_state is None
            else init_state.astype(x.dtype))
    final_state, entering = jax.lax.scan(step, init,
                                         (jnp.moveaxis(states, 1, 0),
                                          jnp.moveaxis(chunk_decay, 1, 0)))
    entering = jnp.moveaxis(entering, 0, 1)            # (B,NC,H,N,P)

    y_off = jnp.einsum("bcln,bclh,bchnp->bclhp", cc, jnp.exp(acs), entering)
    y = (y + y_off).reshape(bsz, nc * l, h, p)[:, :s]
    if return_state:
        # note: with right-padding the pad steps have dt≈softplus(0)>0 but x=0, so
        # they decay the state; callers that prefill must pass unpadded lengths
        return y, final_state
    return y


def ssm_block(params, x: Array, cfg: ModelConfig) -> Array:
    """Full-sequence mamba-2 mixer. x: (B,S,D) -> (B,S,D).

    SSD sharding: heads don't divide typical TP axes (24 heads / 16-way), so the
    state expansion is sharded on the head_dim p (always 2^k): every SSD einsum
    then has p as a pure batch dim — no contraction over a sharded dim, hence no
    per-layer all-reduces inside the chunk scan. B/C/dt are small and replicated."""
    d_inner, h, n, p = dims(cfg)
    z, xbc, dt = _split(params, x, cfg)
    xbc = _conv_train(params, xbc)
    xs = constrain(xbc[..., :d_inner].reshape(*x.shape[:2], h, p),
                   DP, None, None, "model")
    b = xbc[..., d_inner:d_inner + n]
    c = xbc[..., d_inner + n:]
    y = ssd_chunked(xs.astype(jnp.float32), dt, params["A_log"],
                    b.astype(jnp.float32), c.astype(jnp.float32), cfg.ssm_chunk)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg)
    return y @ params["out_proj"]


def ssm_block_prefill(params, x: Array, cfg: ModelConfig, cache: dict):
    """Full-sequence pass that also produces the decode cache (seq_len must be a
    multiple of cfg.ssm_chunk so padded steps don't decay the state)."""
    d_inner, h, n, p = dims(cfg)
    z, xbc, dt = _split(params, x, cfg)
    xbc_c = _conv_train(params, xbc)
    xs = xbc_c[..., :d_inner].reshape(*x.shape[:2], h, p)
    b = xbc_c[..., d_inner:d_inner + n]
    c = xbc_c[..., d_inner + n:]
    y, state = ssd_chunked(xs.astype(jnp.float32), dt, params["A_log"],
                           b.astype(jnp.float32), c.astype(jnp.float32),
                           cfg.ssm_chunk, return_state=True)
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg)
    k = cfg.ssm_conv - 1
    conv_tail = xbc[:, -k:] if xbc.shape[1] >= k else jnp.pad(
        xbc, ((0, 0), (k - xbc.shape[1], 0), (0, 0)))
    return y @ params["out_proj"], {"conv": conv_tail, "state": state}


def _conv_resume(params, xbc: Array, tail: Array) -> Array:
    """Causal depthwise conv resumed mid-sequence: ``tail`` is the previous
    chunk's last ``ssm_conv - 1`` *pre-conv* projections. Same multiply-add
    order as ``_conv_train`` (whose zero left-padding this generalizes), so a
    chunk with a zero tail is bitwise-identical to the sequence start."""
    w = params["conv_w"]
    k, s = w.shape[0], xbc.shape[1]
    ext = jnp.concatenate([tail, xbc], axis=1)         # (B, k-1+S, C)
    out = xbc * w[-1]
    for i in range(1, k):
        out = out + ext[:, k - 1 - i:k - 1 - i + s] * w[-1 - i]
    return jax.nn.silu(out + params["conv_b"].astype(out.dtype))


def ssm_block_prefill_chunk(params, x: Array, cfg: ModelConfig, cache: dict):
    """Chunk-resume prefill: consumes the incoming cache (conv tail + recurrence
    state) and threads it to the next chunk. Bitwise-identical to one-shot
    ``ssm_block_prefill`` when the prompt and every chunk are multiples of
    ``cfg.ssm_chunk`` (the serving engine enforces this before chunking)."""
    d_inner, h, n, p = dims(cfg)
    z, xbc, dt = _split(params, x, cfg)
    xbc_c = _conv_resume(params, xbc, cache["conv"])
    xs = xbc_c[..., :d_inner].reshape(*x.shape[:2], h, p)
    b = xbc_c[..., d_inner:d_inner + n]
    c = xbc_c[..., d_inner + n:]
    y, state = ssd_chunked(xs.astype(jnp.float32), dt, params["A_log"],
                           b.astype(jnp.float32), c.astype(jnp.float32),
                           cfg.ssm_chunk, return_state=True,
                           init_state=cache["state"])
    y = y + params["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg)
    k = cfg.ssm_conv - 1
    conv_tail = jnp.concatenate([cache["conv"], xbc], axis=1)[:, -k:]
    return y @ params["out_proj"], {"conv": conv_tail,
                                    "state": state.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# decode path: O(1) per token
# ---------------------------------------------------------------------------
def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_inner, h, n, p = dims(cfg)
    conv_dim = d_inner + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, h, n, p), jnp.float32),
    }


def ssm_block_decode(params, x: Array, cfg: ModelConfig, cache: dict):
    """One-token step. x: (B,1,D) -> (B,1,D), new cache."""
    d_inner, h, n, p = dims(cfg)
    z, xbc, dt = _split(params, x, cfg)                # (B,1,...)
    window = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B,K,conv_dim)
    conv = jnp.einsum("bkc,kc->bc", window, params["conv_w"])
    xbc1 = jax.nn.silu(conv + params["conv_b"]).astype(x.dtype)

    xs = xbc1[..., :d_inner].reshape(-1, h, p).astype(jnp.float32)
    b = xbc1[..., d_inner:d_inner + n].astype(jnp.float32)
    c = xbc1[..., d_inner + n:].astype(jnp.float32)
    dt1 = dt[:, 0]                                     # (B,H)

    decay = jnp.exp(dt1 * (-jnp.exp(params["A_log"])))  # (B,H)
    xdt = xs * dt1[..., None]                           # (B,H,P)
    state = cache["state"] * decay[..., None, None] \
        + jnp.einsum("bn,bhp->bhnp", b, xdt)
    y = jnp.einsum("bn,bhnp->bhp", c, state)
    y = y + params["D"][:, None] * xs
    y = y.reshape(-1, 1, d_inner).astype(x.dtype)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg)
    new_cache = {"conv": window[:, 1:], "state": state}
    return y @ params["out_proj"], new_cache


def ssd_reference(x: Array, dt: Array, a_log: Array, b: Array, c: Array) -> Array:
    """Naive sequential recurrence — oracle for ssd_chunked."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    decay = jnp.exp(dt * (-jnp.exp(a_log)))            # (B,S,H)
    xdt = x * dt[..., None]

    def step(state, t):
        state = state * decay[:, t][..., None, None] \
            + jnp.einsum("bn,bhp->bhnp", b[:, t], xdt[:, t])
        y = jnp.einsum("bn,bhnp->bhp", c[:, t], state)
        return state, y

    init = jnp.zeros((bsz, h, n, p), x.dtype)
    _, ys = jax.lax.scan(step, init, jnp.arange(s))
    return jnp.moveaxis(ys, 0, 1)
