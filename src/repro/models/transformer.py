"""Decoder stack orchestration: blocks, scan-over-layers, hybrid patterns, KV caches.

Layers are stacked (leading dim = depth) and applied with ``lax.scan`` — this keeps
the HLO size O(1) in depth (compile time and program size matter at 61-layer/1T scale)
and is the unit remat wraps around. Hybrid archs (recurrentgemma) tile their
``block_pattern`` as scan-over-groups plus an unrolled tail.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers, moe, rglru, ssm
from .layers import init_rmsnorm, rmsnorm

Array = jax.Array

MIXER_KINDS = ("attn", "local", "moe", "ssm", "rglru")


def layer_kinds(cfg: ModelConfig) -> list[str]:
    if cfg.family == "moe":
        return ["moe"] * cfg.num_layers
    if cfg.family == "ssm":
        return ["ssm"] * cfg.num_layers
    if cfg.family == "hybrid":
        pat = list(cfg.block_pattern)
        return [pat[i % len(pat)] for i in range(cfg.num_layers)]
    return ["attn"] * cfg.num_layers


def segments(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """Decompose the depth into (pattern, repeats) scan segments."""
    kinds = layer_kinds(cfg)
    if cfg.family == "hybrid":
        pat = tuple(cfg.block_pattern)
        reps = cfg.num_layers // len(pat)
        segs = [(pat, reps)] if reps else []
        tail = tuple(kinds[reps * len(pat):])
        if tail:
            segs.append((tail, 1))
        return segs
    return [((kinds[0],), cfg.num_layers)]


# ---------------------------------------------------------------------------
# one block
# ---------------------------------------------------------------------------
def init_block(key, cfg: ModelConfig, kind: str) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"norm1": init_rmsnorm(cfg.d_model)}
    if kind in ("attn", "local"):
        p["mixer"] = layers.init_attention(k1, cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = layers.init_mlp(k2, cfg)
    elif kind == "moe":
        p["mixer"] = layers.init_attention(k1, cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["moe"] = moe.init_moe(k2, cfg)
    elif kind == "ssm":
        p["mixer"] = ssm.init_ssm(k1, cfg)
    elif kind == "rglru":
        p["mixer"] = rglru.init_rglru(k1, cfg)
        p["norm2"] = init_rmsnorm(cfg.d_model)
        p["mlp"] = layers.init_mlp(k2, cfg)
    else:
        raise ValueError(kind)
    return p


def apply_block(p, x: Array, cfg: ModelConfig, kind: str,
                bias: Optional[Array] = None, prefix_len: Optional[Array] = None):
    """Full-sequence block. Returns (x, moe_stats | None)."""
    stats = None
    x = layers.constrain(x, layers.DP, None, None)
    h = rmsnorm(p["norm1"], x, cfg)
    if kind in ("attn", "moe"):
        x = x + layers.attention(p["mixer"], h, cfg, prefix_len=prefix_len)
    elif kind == "local":
        x = x + layers.attention(p["mixer"], h, cfg, window=cfg.local_window,
                                 prefix_len=prefix_len)
    elif kind == "ssm":
        return x + ssm.ssm_block(p["mixer"], h, cfg), None
    elif kind == "rglru":
        x = x + rglru.rglru_block(p["mixer"], h, cfg)
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, stats


def apply_block_decode(p, x: Array, cfg: ModelConfig, kind: str, cache, pos,
                       bias: Optional[Array] = None,
                       table: Optional[Array] = None,
                       active: Optional[Array] = None,
                       attn_backend: str = "xla"):
    """One-token block step. Returns (x, new_cache, moe_stats | None).
    ``table``/``active`` switch full-attention layers onto the paged KV path
    (serving engine); sliding-window and recurrent layers keep their slot-row
    caches either way. ``attn_backend`` selects the paged attention compute
    (XLA gather oracle vs the Pallas block-table kernel)."""
    stats = None
    h = rmsnorm(p["norm1"], x, cfg)
    if kind in ("attn", "moe"):
        if table is not None:
            y, cache = layers.attention_decode_paged(p["mixer"], h, cfg, cache,
                                                     pos, table, active,
                                                     backend=attn_backend)
        else:
            y, cache = layers.attention_decode(p["mixer"], h, cfg, cache, pos)
        x = x + y
    elif kind == "local":
        y, cache = layers.attention_decode(p["mixer"], h, cfg, cache, pos,
                                           window=cfg.local_window)
        x = x + y
    elif kind == "ssm":
        y, cache = ssm.ssm_block_decode(p["mixer"], h, cfg, cache)
        return x + y, cache, None
    elif kind == "rglru":
        y, cache = rglru.rglru_block_decode(p["mixer"], h, cfg, cache)
        x = x + y
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, cache, stats


def apply_block_verify(p, x: Array, cfg: ModelConfig, kind: str, cache,
                       pos, bias: Optional[Array] = None,
                       table: Optional[Array] = None,
                       active: Optional[Array] = None,
                       attn_backend: str = "xla"):
    """Sq-position verify block step (self-speculative decoding) against the
    paged pool. Attention-stack kinds only: attn/moe carry no slot-row state,
    so every (lane, position) row is independent — per row this is bitwise the
    ``apply_block_decode`` computation at that position. Recurrent kinds have
    cross-position state and are not verifiable in one batched step; the
    engine gates speculative decoding to attention stacks."""
    if kind not in ("attn", "moe"):
        raise NotImplementedError(f"verify step unsupported for {kind!r}")
    stats = None
    h = rmsnorm(p["norm1"], x, cfg)
    y, cache = layers.attention_verify_paged(p["mixer"], h, cfg, cache, pos,
                                             table, active,
                                             backend=attn_backend)
    x = x + y
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, cache, stats


def apply_block_prefill(p, x: Array, cfg: ModelConfig, kind: str, cache,
                        bias: Optional[Array] = None,
                        prefix_len: Optional[Array] = None):
    """Full-sequence block that also fills the decode cache."""
    stats = None
    h = rmsnorm(p["norm1"], x, cfg)
    if kind in ("attn", "moe"):
        y, cache = layers.attention_prefill(p["mixer"], h, cfg, cache,
                                            prefix_len=prefix_len)
        x = x + y
    elif kind == "local":
        y, cache = layers.attention_prefill(p["mixer"], h, cfg, cache,
                                            window=cfg.local_window,
                                            prefix_len=prefix_len)
        x = x + y
    elif kind == "ssm":
        y, cache = ssm.ssm_block_prefill(p["mixer"], h, cfg, cache)
        return x + y, cache, None
    elif kind == "rglru":
        y, cache = rglru.rglru_block_prefill(p["mixer"], h, cfg, cache)
        x = x + y
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, cache, stats


def apply_block_prefill_chunk(p, x: Array, cfg: ModelConfig, kind: str, cache,
                              table_row: Array, p0: Array,
                              bias: Optional[Array] = None):
    """One prefill-chunk block step against the paged pool (full attention) or
    the slot's recurrent state row (SSM). Sliding-window and RG-LRU layers are
    not chunkable (ring-slot remapping / associative-scan splits change the
    numerics) — the engine routes those configs to one-shot prefill."""
    stats = None
    h = rmsnorm(p["norm1"], x, cfg)
    if kind in ("attn", "moe"):
        y, cache = layers.attention_prefill_paged(p["mixer"], h, cfg, cache,
                                                  table_row, p0)
        x = x + y
    elif kind == "ssm":
        y, cache = ssm.ssm_block_prefill_chunk(p["mixer"], h, cfg, cache)
        return x + y, cache, None
    else:
        raise NotImplementedError(f"chunked prefill unsupported for {kind!r}")
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, cache, stats


def apply_block_prefill_chunk_multi(p, x: Array, cfg: ModelConfig, kind: str,
                                    cache, tables: Array, p0s: Array,
                                    bias: Optional[Array] = None):
    """J concurrent prefill-chunk block steps against the paged pool in one
    call — attention-stack kinds only (attn/moe carry no slot-row cache, so
    lanes are fully independent; recurrent kinds stay on the one-job path)."""
    if kind not in ("attn", "moe"):
        raise NotImplementedError(f"batched chunk prefill unsupported for "
                                  f"{kind!r}")
    stats = None
    h = rmsnorm(p["norm1"], x, cfg)
    y, cache = layers.attention_prefill_paged_multi(p["mixer"], h, cfg, cache,
                                                    tables, p0s)
    x = x + y
    if kind == "moe":
        y, stats = moe.moe_ffn(p["moe"], rmsnorm(p["norm2"], x, cfg), cfg, bias)
        x = x + y
    else:
        x = x + layers.mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg), cfg)
    return x, cache, stats


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, s_max: int, dtype):
    if kind in ("attn", "moe"):
        return layers.init_attention_cache(cfg, batch, s_max, dtype)
    if kind == "local":
        return layers.init_attention_cache(cfg, batch, min(s_max, cfg.local_window),
                                           dtype)
    if kind == "ssm":
        return ssm.init_ssm_cache(cfg, batch, dtype)
    if kind == "rglru":
        return rglru.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the stack
# ---------------------------------------------------------------------------
def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def init_stack(key, cfg: ModelConfig) -> list:
    """Returns a list (one per segment) of per-position stacked param trees."""
    segs = segments(cfg)
    out = []
    for si, (pattern, reps) in enumerate(segs):
        seg_params = []
        for pi, kind in enumerate(pattern):
            keys = jax.random.split(jax.random.fold_in(key, si * 97 + pi), reps)
            seg_params.append(jax.vmap(lambda k, kd=kind: init_block(k, cfg, kd))(keys))
        out.append(seg_params)
    return out


def apply_stack(stack_params: list, x: Array, cfg: ModelConfig,
                bias: Optional[Array] = None, prefix_len: Optional[Array] = None):
    """Full-sequence pass. ``bias``: (num_layers, E) immune router bias for MoE.
    Returns (x, stats (num_layers, E) load fractions | None, aux_loss, drop_frac)."""
    li = 0
    loads, auxs, drops = [], [], []
    for (pattern, reps), seg_params in zip(segments(cfg), stack_params):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            seg_bias = bias[li:li + reps * npos].reshape(reps, npos, -1)
        li += reps * npos

        def body(carry, inp, pattern=pattern, npos=npos):
            xc = carry
            lp, b = inp
            sts = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, st = apply_block(lp[pi], xc, cfg, kind, bias=bi,
                                     prefix_len=prefix_len)
                if st is not None:
                    sts.append(st)
            out_st = jax.tree.map(lambda *a: jnp.stack(a), *sts) if sts else 0
            return xc, out_st

        body = _maybe_remat(body, cfg)
        xs = (seg_params, seg_bias)
        x, seg_stats = jax.lax.scan(body, x, xs)
        if isinstance(seg_stats, moe.MoEStats):
            loads.append(seg_stats.load_frac.reshape(-1, cfg.num_experts))
            auxs.append(seg_stats.aux_loss.reshape(-1))
            drops.append(seg_stats.drop_frac.reshape(-1))
    if loads:
        return (x, jnp.concatenate(loads), jnp.mean(jnp.concatenate(auxs)),
                jnp.mean(jnp.concatenate(drops)))
    return x, None, jnp.zeros(()), jnp.zeros(())


def init_stack_cache(cfg: ModelConfig, batch: int, s_max: int, dtype) -> list:
    """Stacked decode caches, mirroring the segment structure."""
    out = []
    for pattern, reps in segments(cfg):
        seg = []
        for kind in pattern:
            one = init_block_cache(cfg, kind, batch, s_max, dtype)
            seg.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), one))
        out.append(seg)
    return out


def map_block_caches(cfg: ModelConfig, fn, *trees):
    """Apply ``fn(kind, *per-layer-cache-dicts)`` across the stacked segment
    structure of one or more stack-cache trees, preserving the structure. The
    kind-aware analogue of ``jax.tree.map`` — paged full-attention leaves and
    slot-row recurrent leaves need different surgery and can't be told apart by
    leaf shape alone."""
    out = []
    for si, (pattern, reps) in enumerate(segments(cfg)):
        seg = []
        for pi, kind in enumerate(pattern):
            seg.append(fn(kind, *(t[si][pi] for t in trees)))
        out.append(seg)
    return out


def init_stack_cache_paged(cfg: ModelConfig, num_slots: int, s_max: int,
                           num_pages: int, page_size: int, dtype) -> list:
    """Paged decode caches: full-attention layers get a physical page pool
    (shared free list across slots, per-layer storage under one block table);
    sliding-window layers keep bounded slot-row ring buffers and recurrent
    layers their O(1) slot-row states — none of those holds a worst-case
    sequence reservation, so only full attention needs paging."""
    out = []
    for pattern, reps in segments(cfg):
        seg = []
        for kind in pattern:
            if kind in ("attn", "moe"):
                one = layers.init_attention_cache_paged(cfg, num_pages,
                                                        page_size, dtype)
            else:
                one = init_block_cache(cfg, kind, num_slots, s_max, dtype)
            seg.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), one))
        out.append(seg)
    return out


def apply_stack_prefill(stack_params: list, x: Array, cfg: ModelConfig, caches: list,
                        bias: Optional[Array] = None,
                        prefix_len: Optional[Array] = None):
    """Full-sequence pass that fills the decode caches. Returns (x, new_caches)."""
    li = 0
    new_caches = []
    for (pattern, reps), seg_params, seg_cache in zip(segments(cfg), stack_params,
                                                      caches):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            seg_bias = bias[li:li + reps * npos].reshape(reps, npos, -1)
        li += reps * npos

        def body(carry, inp, pattern=pattern):
            xc = carry
            lp, cs, b = inp
            new_cs = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, c2, _ = apply_block_prefill(lp[pi], xc, cfg, kind, cs[pi],
                                                bias=bi, prefix_len=prefix_len)
                new_cs.append(c2)
            return xc, new_cs

        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache, seg_bias))
        new_caches.append(nc)
    return x, new_caches


def apply_stack_decode(stack_params: list, x: Array, cfg: ModelConfig, caches: list,
                       pos: Array, bias: Optional[Array] = None,
                       table: Optional[Array] = None,
                       active: Optional[Array] = None,
                       attn_backend: str = "xla"):
    """One-token pass. Returns (x, new_caches). ``table``/``active`` select the
    paged KV path for full-attention layers (closed over, same for every layer);
    ``attn_backend`` picks its compute (XLA gather vs Pallas kernel). The bias
    rows scanned follow the *params'* repetition depth, not the config's, so a
    ``truncate_stack`` draft slice takes the leading layers' bias rows."""
    li = 0
    new_caches = []
    for (pattern, reps), seg_params, seg_cache in zip(segments(cfg), stack_params,
                                                      caches):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            reps_p = jax.tree_util.tree_leaves(seg_params)[0].shape[0]
            seg_bias = bias[li:li + reps_p * npos].reshape(reps_p, npos, -1)
        li += reps * npos

        def body(carry, inp, pattern=pattern):
            xc = carry
            lp, cs, b = inp
            new_cs = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, c2, _ = apply_block_decode(lp[pi], xc, cfg, kind, cs[pi], pos,
                                               bias=bi, table=table,
                                               active=active,
                                               attn_backend=attn_backend)
                new_cs.append(c2)
            return xc, new_cs

        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache, seg_bias))
        new_caches.append(nc)
    return x, new_caches


def apply_stack_verify(stack_params: list, x: Array, cfg: ModelConfig,
                       caches: list, pos: Array,
                       bias: Optional[Array] = None,
                       table: Optional[Array] = None,
                       active: Optional[Array] = None,
                       attn_backend: str = "xla"):
    """Sq-position verify pass (self-speculative decoding): every lane scores
    ``Sq`` consecutive positions starting at its ``pos`` in one batched step.
    Attention stacks only. Returns (x, new_caches)."""
    li = 0
    new_caches = []
    for (pattern, reps), seg_params, seg_cache in zip(segments(cfg), stack_params,
                                                      caches):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            seg_bias = bias[li:li + reps * npos].reshape(reps, npos, -1)
        li += reps * npos

        def body(carry, inp, pattern=pattern):
            xc = carry
            lp, cs, b = inp
            new_cs = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, c2, _ = apply_block_verify(lp[pi], xc, cfg, kind, cs[pi],
                                               pos, bias=bi, table=table,
                                               active=active,
                                               attn_backend=attn_backend)
                new_cs.append(c2)
            return xc, new_cs

        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache, seg_bias))
        new_caches.append(nc)
    return x, new_caches


def truncate_stack(stack: list, depth: int) -> list:
    """First-``depth``-layer slice of a stacked param/cache tree (single-
    segment attention stacks — the self-speculative draft's weight reuse).
    The slice shares the leading-axis layout, so ``apply_stack_decode`` runs
    it unchanged: ``lax.scan`` infers the shorter depth from the sliced
    leading axis. Layer d's input depends only on layers < d, so the sliced
    pool's K/V *is* the truncated-depth model's cache — no separate draft
    weights or draft cache exist."""
    return [[jax.tree.map(lambda a: a[:depth], pos_params)
             for pos_params in seg] for seg in stack]


def apply_stack_prefill_chunk(stack_params: list, x: Array, cfg: ModelConfig,
                              caches: list, table_row: Array, p0: Array,
                              slot: Array, bias: Optional[Array] = None):
    """One prefill-chunk pass (batch-of-1) against the paged pool. Full-attention
    layers write the chunk's K/V into the slot's pages; recurrent (SSM) layers
    thread the slot's state row across chunks. Returns (x, new_caches)."""
    li = 0
    new_caches = []
    for (pattern, reps), seg_params, seg_cache in zip(segments(cfg), stack_params,
                                                      caches):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            seg_bias = bias[li:li + reps * npos].reshape(reps, npos, -1)
        li += reps * npos
        # recurrent leaves are slot-indexed (reps, S, ...): slice the slot's row
        # outside the scan, write it back after
        seg_in = [jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(
                      a, slot, 1, axis=1), cs) if kind not in ("attn", "moe")
                  else cs for kind, cs in zip(pattern, seg_cache)]

        def body(carry, inp, pattern=pattern):
            xc = carry
            lp, cs, b = inp
            new_cs = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, c2, _ = apply_block_prefill_chunk(lp[pi], xc, cfg, kind,
                                                      cs[pi], table_row, p0,
                                                      bias=bi)
                new_cs.append(c2)
            return xc, new_cs

        x, nc = jax.lax.scan(body, x, (seg_params, seg_in, seg_bias))
        nc = [jax.tree.map(lambda full, row: jax.lax.dynamic_update_slice_in_dim(
                  full, row.astype(full.dtype), slot, axis=1), cs, c2)
              if kind not in ("attn", "moe") else c2
              for kind, cs, c2 in zip(pattern, seg_cache, nc)]
        new_caches.append(nc)
    return x, new_caches


def apply_stack_prefill_chunk_multi(stack_params: list, x: Array,
                                    cfg: ModelConfig, caches: list,
                                    tables: Array, p0s: Array,
                                    bias: Optional[Array] = None):
    """J concurrent prefill chunks (one lane per in-flight job) in a single
    pass against the paged pool — attn/moe stacks only, so there is no
    slot-row state to slice and every lane is independent. Padding lanes carry
    an all-null block table (writes land on the trash page, outputs are
    discarded by the host). Returns (x, new_caches)."""
    li = 0
    new_caches = []
    for (pattern, reps), seg_params, seg_cache in zip(segments(cfg), stack_params,
                                                      caches):
        npos = len(pattern)
        seg_bias = None
        if bias is not None:
            seg_bias = bias[li:li + reps * npos].reshape(reps, npos, -1)
        li += reps * npos

        def body(carry, inp, pattern=pattern):
            xc = carry
            lp, cs, b = inp
            new_cs = []
            for pi, kind in enumerate(pattern):
                bi = None if b is None else b[pi]
                xc, c2, _ = apply_block_prefill_chunk_multi(
                    lp[pi], xc, cfg, kind, cs[pi], tables, p0s, bias=bi)
                new_cs.append(c2)
            return xc, new_cs

        x, nc = jax.lax.scan(body, x, (seg_params, seg_cache, seg_bias))
        new_caches.append(nc)
    return x, new_caches
