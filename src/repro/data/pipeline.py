"""Deterministic, checkpointable synthetic data pipeline.

Real corpora are out of scope offline; what the framework needs from a pipeline is
exercised fully: deterministic sharded iteration (every DP rank derives its shard
from (step, rank) — no host state to lose), checkpointability (the iterator state is
just the step counter), and a learnable distribution (a fixed random bigram chain, so
training loss measurably falls — used by the convergence tests and examples).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Array = jax.Array


class DataState(NamedTuple):
    step: Array          # () int32 — the only iterator state


def init_data_state() -> DataState:
    return DataState(step=jnp.zeros((), jnp.int32))


def _bigram_table(vocab: int, seed: int, branch: int = 4) -> Array:
    """Each token deterministically allows ``branch`` successors — low-entropy
    language a small model can learn."""
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (vocab, branch), 0, vocab, jnp.int32)


def sample_batch(cfg: ModelConfig, batch: int, seq: int, state: DataState,
                 seed: int = 1234) -> tuple[dict, DataState]:
    """Deterministic batch at ``state.step``. jit-safe; no host randomness."""
    table = _bigram_table(cfg.vocab_size, seed)
    branch = table.shape[1]
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), state.step)
    k0, k1, k2 = jax.random.split(key, 3)
    first = jax.random.randint(k0, (batch,), 0, cfg.vocab_size, jnp.int32)
    choices = jax.random.randint(k1, (batch, seq), 0, branch, jnp.int32)

    def step_fn(tok, choice):
        nxt = table[tok, choice]
        return nxt, tok

    _, toks = jax.lax.scan(step_fn, first, jnp.moveaxis(choices, 1, 0))
    tokens = jnp.moveaxis(toks, 0, 1)                   # (B, S)
    out = {"tokens": tokens}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k2, (batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            k2, (batch, seq, cfg.frontend_dim), jnp.float32)
    return out, DataState(step=state.step + 1)
