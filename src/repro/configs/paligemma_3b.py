"""PaliGemma-3B [arXiv:2407.07726; hf] — SigLIP stub prefix + gemma-2b decoder (MQA).

The SigLIP tower is a stub per the assignment: input_specs provides 256 precomputed
patch embeddings of width 1152; the backbone sees a learned projection of them as a
bidirectional prefix (prefix-LM masking).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16_384, vocab_size=257_216,
    act="gelu", tie_embeddings=True, scale_embeddings=True, use_plus_one_norm=True,
    frontend_tokens=256, frontend_dim=1152,
)
