"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M] — llama-arch small, GQA kv=5."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49_152,
    act="silu", tie_embeddings=True,
)
