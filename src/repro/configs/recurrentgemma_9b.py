"""RecurrentGemma-9B [arXiv:2402.19427] — RG-LRU + local attention, 1:2 pattern.

38 layers tile the (rglru, rglru, local-attn) Griffin pattern: 12 full groups + a
2-layer recurrent tail. Sub-quadratic: runs the long_500k decode shape (local
attention is ring-buffered at window=2048; recurrences are O(1) state).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12_288, vocab_size=256_000,
    act="gelu", tie_embeddings=True, scale_embeddings=True, use_plus_one_norm=True,
    block_pattern=("rglru", "rglru", "local"), lru_width=4096, local_window=2048,
)
