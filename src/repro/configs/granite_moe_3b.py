"""Granite-MoE 3B-A800M [hf:ibm-granite] — 40 experts top-8, immune-balanced router."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    act="silu", tie_embeddings=True,
    num_experts=40, experts_per_token=8, capacity_factor=1.25,
    router_mode="immune",
)
