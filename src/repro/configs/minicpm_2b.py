"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like dense, MHA, WSD schedule."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", family="dense",
    num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36, head_dim=64,
    d_ff=5760, vocab_size=122_753,
    act="silu", tie_embeddings=True,
)
# MiniCPM trains with the WSD (warmup-stable-decay) schedule:
TRAIN_SCHEDULE = "wsd"
