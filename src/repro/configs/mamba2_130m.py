"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality), attention-free.

Sub-quadratic: runs the long_500k decode shape with O(1) per-token state.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50_280,
    tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=64,
)
