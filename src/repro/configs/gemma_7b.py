"""Gemma-7B [arXiv:2403.08295; hf] — GeGLU, head_dim=256, scaled embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24_576, vocab_size=256_000,
    act="gelu", tie_embeddings=True, scale_embeddings=True, use_plus_one_norm=True,
)
