"""Qwen3-4B [hf:Qwen/Qwen3-*] — GQA kv=8, qk-norm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    num_layers=36, d_model=2560, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151_936,
    act="silu", qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=True,
)
