"""Kimi K2 1T-A32B [arXiv:2501.kimi2; paper-table, unverified] — 384 experts top-8.

Assignment specifies GQA kv=8 (the production model uses MLA; the paper-table entry
pins GQA, which we follow). The trillion parameters live in the 61x384 expert FFNs;
expert-parallel sharding over the 'model' axis is mandatory (dist/sharding.py).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163_840,
    act="silu", tie_embeddings=True,
    num_experts=384, experts_per_token=8, capacity_factor=1.25,
    router_mode="immune",
)
