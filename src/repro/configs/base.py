"""Config schema: model architectures, input shapes, parallelism and training knobs."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention / norm flavour
    act: str = "silu"              # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    scale_embeddings: bool = False  # gemma-style sqrt(d_model) embedding scale
    norm_eps: float = 1e-6
    use_plus_one_norm: bool = False  # gemma-style (1 + g) RMSNorm scale

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_mode: str = "immune"    # immune | aux | sign | none
    aux_loss_coef: float = 0.01
    # dispatch locality: tokens are sorted/bucketed within G groups (launchers set
    # G = the DP shard count so the sort never crosses devices; 1 = global)
    dispatch_groups: int = 1

    # SSM (mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # hybrid (recurrentgemma): temporal-mixing pattern tiled over the depth,
    # e.g. ("rglru", "rglru", "attn") -> 1:2 attention:recurrence
    block_pattern: tuple[str, ...] = ()
    lru_width: int = 0
    local_window: int = 2048

    # modality frontend stubs (vlm / audio): precomputed embeddings from input_specs
    frontend_tokens: int = 0       # e.g. SigLIP patches or EnCodec frames
    frontend_dim: int = 0

    # numerics
    dtype: str = "bfloat16"
    remat: str = "none"            # none | dots | full

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_subquadratic_path(self) -> bool:
        """Can this arch serve a 512k-token context without full quadratic attention?"""
        return self.family in ("ssm", "hybrid")

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests."""
        kw = dict(
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            frontend_tokens=min(self.frontend_tokens, 8) if self.frontend_tokens else 0,
            frontend_dim=32 if self.frontend_dim else 0,
            lru_width=64 if self.lru_width else 0,
            local_window=32 if self.block_pattern else 2048,
            dtype="float32",
        )
        if self.num_experts:
            kw.update(num_experts=8, experts_per_token=2)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """Sharding strategy knobs (the §Perf hillclimb axes)."""

    fsdp: bool = True              # shard params/optimizer over 'data' (ZeRO-3 style)
    seq_shard: bool = False        # shard sequence dim over 'model' for long prefill
    expert_parallel: bool = True   # shard MoE experts over 'model'
    remat: str = "none"
    capacity_factor: Optional[float] = None  # override model capacity factor


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    schedule: str = "cosine"       # cosine | wsd (warmup-stable-decay)
    stable_frac: float = 0.8       # wsd: fraction of decay_steps held stable
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    accum_steps: int = 1
    seed: int = 0


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
