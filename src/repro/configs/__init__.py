"""Architecture registry: ``get_config(arch_id)`` + input_specs per (arch, shape).

The 10 assigned architectures (each cell of the 40 (arch x shape) dry-run grid is
well-defined by pairing an arch with its shape set — all four LM shapes here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import (gemma_7b, granite_moe_3b, kimi_k2, mamba2_130m, minicpm_2b,
               musicgen_medium, paligemma_3b, qwen3_4b, recurrentgemma_9b,
               smollm_360m)
from .base import SHAPES, ModelConfig, ParallelConfig, ShapeConfig, TrainConfig

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (minicpm_2b, smollm_360m, gemma_7b, qwen3_4b, paligemma_3b,
              granite_moe_3b, kimi_k2, recurrentgemma_9b, mamba2_130m,
              musicgen_medium)
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCHS)}")
    return ARCHS[arch]


def get_shape(shape: str) -> ShapeConfig:
    if shape not in SHAPES:
        raise KeyError(f"unknown shape {shape!r}; available: {sorted(SHAPES)}")
    return SHAPES[shape]


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether the (arch, shape) cell runs. long_500k needs a sub-quadratic path
    (assignment: skip for pure full-attention archs, run for SSM/hybrid)."""
    if shape.name == "long_500k" and not cfg.has_subquadratic_path:
        return False, ("skipped: full-attention arch has no sub-quadratic path for "
                       "a 512k-token context (see DESIGN.md §6)")
    return True, "ok"


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell — weak-type
    correct, shardable, no device allocation. Used by the dry-run and the roofline
    harness."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": sds((b, s), i32)}
        if cfg.family == "vlm":
            batch["patches"] = sds((b, cfg.frontend_tokens, cfg.frontend_dim), f32)
        if cfg.family == "audio":
            batch["frames"] = sds((b, s, cfg.frontend_dim), f32)
        return batch
    # decode: one new token against a cache of seq_len
    batch = {"token": sds((b, 1), i32)}
    if cfg.family == "audio":
        batch["frame"] = sds((b, 1, cfg.frontend_dim), f32)
    return batch
