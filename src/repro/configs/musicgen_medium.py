"""MusicGen-medium [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

The EnCodec codec is a stub per the assignment: input_specs provides precomputed
frame embeddings (width 128, EnCodec's latent dim) that are added to the code-token
embeddings; the backbone predicts the next code (vocab 2048 per codebook).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    act="gelu", tie_embeddings=False,
    frontend_tokens=0, frontend_dim=128,
)
