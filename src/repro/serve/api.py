"""Unified serving API: the request-facing types both serving backends speak.

The engine (``serve.engine.Engine``) and one-shot decode (``serve.decode``)
are two backends of one front door:

  * ``SamplingParams`` — per-request decoding intent: temperature / top-p /
    top-k / seed, token budget (``max_new_tokens``), stop-token ids.
    ``temperature == 0`` is *exact* greedy — bitwise the argmax path.
  * ``ServeRequest``   — prompt + params + the scheduling metadata the immune
    admission loop reads (``rclass``, ``arrival``, optional per-request
    wall-clock ``deadline`` overriding the engine-wide tick budget). This is
    the
    anticipation argument (Boulmier et al., PAPERS.md) made concrete: the
    scheduler sees each request's declared intent, not just its queue slot.
  * ``RequestOutput``  — incremental token deltas plus finish reason and
    per-request tick/wall-clock latency accounting. ``Engine.stream()``
    yields one per request per tick of progress; the one-shot ``generate``
    facade returns one finished output per request.

Sampling itself lives in ``models.model.sample_tokens`` (per-lane masked
top-k/top-p over the logits lane, per-lane PRNG keys folded with the lane's
emitted-token count) so the engine's single compiled decode step and the
one-shot decode loop run the *same* lane math — seeded sampling is then
token-identical engine-vs-oneshot, and the parity oracle can compare raw
logits bitwise below the sampler.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model
from . import decode

Array = jax.Array

GREEDY_TEMPERATURE = 0.0


@dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters. Frozen: shared freely across requests.

    ``temperature == 0`` selects the exact greedy path (bitwise argmax);
    ``top_k == 0`` and ``top_p == 1.0`` disable their filters. ``seed`` fixes
    the request's PRNG key stream, so a seeded request emits identical tokens
    on every run and on either backend. ``stop`` token ids retire the request
    the tick one is emitted (the stop token is included in the output, like
    the old ``eos_id``)."""

    temperature: float = GREEDY_TEMPERATURE
    top_p: float = 1.0
    top_k: int = 0
    seed: int = 0
    max_new_tokens: int = 16
    stop: tuple = ()
    logprobs: int = 0          # k: record each chosen token's logprob (under
    #                            the raw model distribution, before
    #                            temperature) plus the top-k alternative
    #                            logprobs per position; 0 disables. Accepts
    #                            the legacy bool spelling (True == 1).
    repetition_penalty: float = 1.0   # >1 discourages reuse; 1.0 disabled
    presence_penalty: float = 0.0     # flat once-seen penalty; 0.0 disabled
    frequency_penalty: float = 0.0    # per-occurrence penalty; 0.0 disabled
    n: int = 1                 # completions to return (slot-group lanes)
    best_of: int = 0           # 0: off; >= n: sample best_of lanes, keep the
    #                            n best by cumulative chosen-token logprob

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.repetition_penalty <= 0:
            raise ValueError(
                f"repetition_penalty must be > 0, got {self.repetition_penalty}")
        if self.n < 1:
            raise ValueError(f"n must be >= 1, got {self.n}")
        if self.best_of and self.best_of < self.n:
            raise ValueError(
                f"best_of must be 0 or >= n, got {self.best_of} < {self.n}")
        object.__setattr__(self, "logprobs", int(self.logprobs))
        if self.logprobs < 0:
            raise ValueError(f"logprobs must be >= 0, got {self.logprobs}")
        object.__setattr__(self, "stop", tuple(int(t) for t in self.stop))

    @property
    def is_greedy(self) -> bool:
        return self.temperature == GREEDY_TEMPERATURE

    @property
    def has_penalties(self) -> bool:
        return (self.repetition_penalty != 1.0 or self.presence_penalty != 0.0
                or self.frequency_penalty != 0.0)

    @property
    def group_size(self) -> int:
        """Engine lanes this request owns (``best_of`` supersedes ``n``)."""
        return max(self.n, self.best_of, 1)

    def key(self) -> np.ndarray:
        """Host copy of the request's base PRNG key (2,) uint32."""
        return np.asarray(jax.random.PRNGKey(self.seed))


def spec_for(params_list: Sequence[SamplingParams]) -> model.SamplingSpec:
    """Stack per-request ``SamplingParams`` into the per-lane arrays the
    compiled decode steps consume."""
    return model.SamplingSpec(
        keys=jnp.asarray(np.stack([p.key() for p in params_list])),
        temperature=jnp.asarray([p.temperature for p in params_list],
                                jnp.float32),
        top_k=jnp.asarray([p.top_k for p in params_list], jnp.int32),
        top_p=jnp.asarray([p.top_p for p in params_list], jnp.float32),
        rep_penalty=jnp.asarray([p.repetition_penalty for p in params_list],
                                jnp.float32),
        pres_penalty=jnp.asarray([p.presence_penalty for p in params_list],
                                 jnp.float32),
        freq_penalty=jnp.asarray([p.frequency_penalty for p in params_list],
                                 jnp.float32))


@dataclass
class ServeRequest:
    """One serving request: prompt + sampling params + scheduling metadata.

    ``rclass`` buckets requests into the classes the immune admission
    controller remembers (endpoint, tenant, prompt-shape bucket); ``arrival``
    is the tick the request enters the queue; ``deadline`` is **wall-clock
    seconds after submission** and overrides the engine-wide (tick-denominated)
    latency budget for this request's goodput/anergy accounting when set —
    each bar is only ever compared against a latency in its own unit (see
    ``EngineConfig`` and ``Engine._slo``)."""

    rid: int
    tokens: np.ndarray                     # (L,) int32 prompt
    params: SamplingParams = SamplingParams()
    rclass: int = 0
    arrival: int = 0
    deadline: Optional[float] = None
    patches: Optional[np.ndarray] = None   # vlm prefix embeddings (P, Fd)
    frames: Optional[np.ndarray] = None    # audio frame embeddings (L, Fd)

    # slot-group membership (serve.groups): a parent with params.n/best_of > 1
    # is expanded into group_size member lanes sharing its prompt pages.
    # group == -1: standalone request. Members carry the parent rid in
    # ``group`` and their lane index in ``lane``.
    group: int = -1
    lane: int = 0
    group_size: int = 1

    # filled in by the serving backend
    out_tokens: list = field(default_factory=list)
    out_logits: list = field(default_factory=list)  # per-token (V,) fp32 rows
    #                                                 (capture_logits only)
    out_logprobs: list = field(default_factory=list)  # per-token chosen-token
    #                                                   logprob (params.logprobs)
    out_topk: list = field(default_factory=list)  # per-token (ids, logprobs)
    #                                               top-k alternative pairs
    #                                               (params.logprobs == k)
    finish_reason: Optional[str] = None    # "stop" | "length" | "rejected" |
    #                                        "shed" | "failed" | "corrupted"
    admit_tick: int = -1
    finish_tick: int = -1
    slot: int = -1
    submit_time: float = -1.0              # wall clock, perf_counter seconds
    finish_time: float = -1.0
    preemptions: int = 0                   # times evicted from a slot mid-flight
    retries: int = 0                       # times re-placed on a survivor after
    #                                        a replica death (router failover);
    #                                        past the router's retry budget the
    #                                        request terminates with
    #                                        finish_reason="failed"
    replayed_tokens: int = 0               # recorded tokens re-derived by decode
    #                                        after preemption — slot-ticks the
    #                                        request burned beyond its emissions
    requeue_ticks: int = 0                 # ticks spent re-queued after eviction
    preempt_tick: int = -1                 # last eviction tick (-1: not evicted
    #                                        or already re-admitted)
    prefill_tokens: int = 0                # prompt positions actually computed
    #                                        (prefix hits and replays excluded)

    @property
    def max_new_tokens(self) -> int:
        return self.params.max_new_tokens

    @property
    def latency(self) -> int:
        return self.finish_tick - self.arrival

    @property
    def wall_latency_s(self) -> Optional[float]:
        if self.submit_time < 0 or self.finish_time < 0:
            return None
        return self.finish_time - self.submit_time

    def prompts(self) -> dict:
        """The prefill batch-of-1 for this request — the single source of truth
        for what a backend feeds the model (the parity oracle reuses it)."""
        p = {"tokens": jnp.asarray(self.tokens, jnp.int32)[None]}
        if self.patches is not None:
            p["patches"] = jnp.asarray(self.patches)[None]
        if self.frames is not None:
            p["frames"] = jnp.asarray(self.frames)[None]
        return p


@dataclass
class RequestOutput:
    """One increment of a request's progress.

    ``Engine.stream()`` yields one per request per tick it gained tokens or
    changed state; ``new_tokens`` is the delta since the previous output for
    the same ``rid`` and ``tokens`` the full stream so far. Terminal outputs
    set ``finished`` with a ``finish_reason`` ("stop" | "length" on normal
    retirement, "rejected" | "shed" when admission refused the request,
    "failed" when a fleet router exhausted the request's crash-retry budget —
    see ``serve.router`` — and "corrupted" when the silent-corruption guard
    caught non-finite decode logits on the request's lane and retired it
    rather than stream garbage) and
    the latency accounting — ``latency_ticks`` in engine ticks,
    ``wall_latency_s`` in wall-clock seconds, ``deadline_met`` against the
    request's own deadline (or the engine budget). A request still queued or
    in-flight when the engine's ``max_ticks`` backstop fires gets a final
    ``finish_reason="timeout"`` output with ``finished=False`` — the engine
    still holds it and can be stepped further."""

    rid: int
    new_tokens: list
    tokens: list
    finished: bool
    finish_reason: Optional[str]
    tick: int
    arrival: int = 0
    admit_tick: int = -1
    finish_tick: int = -1
    latency_ticks: Optional[int] = None
    wall_latency_s: Optional[float] = None
    deadline_met: Optional[bool] = None
    # chosen-token logprobs (None unless SamplingParams.logprobs): the delta
    # aligned 1:1 with new_tokens, and the full stream aligned with tokens
    new_logprobs: Optional[list] = None
    logprobs: Optional[list] = None
    # top-k alternative logprobs (None unless SamplingParams.logprobs == k):
    # per emitted position an (ids, logprobs) pair of the k highest-probability
    # vocab entries under the raw model distribution, aligned with tokens
    top_logprobs: Optional[list] = None
    # slot-group assembly (None unless the request had params.n/best_of > 1):
    # the parent's view of its member lanes — finished member outputs in rank
    # order (cumulative chosen-token logprob when best_of, lane order for n)
    group_outputs: Optional[list] = None
    # preemption accounting: how often this request was evicted mid-flight
    # and how many ticks it spent re-queued waiting for re-admission
    preemptions: int = 0
    requeue_ticks: int = 0


def _finish_oneshot(req: ServeRequest, stream: list, t0: float) -> RequestOutput:
    """Trim a one-shot token stream at the first stop token (inclusive,
    mirroring the engine's retirement) and fill the request/output records."""
    cut, reason = len(stream), "length"
    for i, t in enumerate(stream):
        if t in req.params.stop:
            cut, reason = i + 1, "stop"
            break
    req.out_tokens = stream[:cut]
    req.finish_reason = reason
    req.finish_time = time.perf_counter()
    if req.submit_time < 0:
        req.submit_time = t0
    return RequestOutput(
        rid=req.rid, new_tokens=list(req.out_tokens),
        tokens=list(req.out_tokens), finished=True, finish_reason=reason,
        tick=len(req.out_tokens), arrival=req.arrival, admit_tick=0,
        finish_tick=len(req.out_tokens),
        latency_ticks=len(req.out_tokens),
        wall_latency_s=req.finish_time - req.submit_time)


def generate(params, cfg: ModelConfig,
             requests: Union[ServeRequest, Sequence[ServeRequest]],
             max_cache: int, router_bias: Optional[Array] = None,
             capture_logits: bool = False
             ) -> Union[RequestOutput, Sequence[RequestOutput]]:
    """One-shot serving facade: prefill + decode each request batch-of-1 under
    its own ``SamplingParams``, returning finished ``RequestOutput``s.

    This is the oracle backend: the engine must emit exactly these tokens for
    the same request (greedy bitwise; seeded sampling token-identical), and
    with ``capture_logits`` each request's per-token logits rows land in
    ``req.out_logits`` for the bitwise logits-parity comparison."""
    from . import groups
    single = isinstance(requests, ServeRequest)
    reqs = [requests] if single else list(requests)
    outs = []
    for req in reqs:
        if req.params.group_size > 1:
            t0 = time.perf_counter()
            members = groups.expand(req)
            member_outs = [_oneshot_one(params, cfg, m, max_cache,
                                        router_bias, capture_logits)
                           for m in members]
            outs.append(groups.assemble(req, members, member_outs, t0))
        else:
            outs.append(_oneshot_one(params, cfg, req, max_cache,
                                     router_bias, capture_logits))
    return outs[0] if single else outs


def _oneshot_one(params, cfg: ModelConfig, req: ServeRequest, max_cache: int,
                 router_bias: Optional[Array], capture_logits: bool
                 ) -> RequestOutput:
    """Run one request batch-of-1 through the one-shot decode loop."""
    t0 = time.perf_counter()
    sp = req.params
    sampling = None if (sp.is_greedy and not sp.has_penalties) \
        else spec_for([sp])
    res = decode.generate(params, cfg, req.prompts(), max_cache=max_cache,
                          steps=sp.max_new_tokens, router_bias=router_bias,
                          sampling=sampling, return_logits=capture_logits,
                          return_logprobs=bool(sp.logprobs),
                          use_penalties=sp.has_penalties,
                          return_topk=sp.logprobs)
    stream = [int(t) for t in np.asarray(res[0][0])]
    out = _finish_oneshot(req, stream, t0)
    idx = 2
    if capture_logits:
        lg = np.asarray(res[idx][0])                       # (steps, V) fp32
        idx += 1
        req.out_logits = [lg[i].copy()
                          for i in range(len(req.out_tokens))]
    if sp.logprobs:
        lp = np.asarray(res[idx][0])                       # (steps,) fp32
        idx += 1
        req.out_logprobs = [float(lp[i])
                            for i in range(len(req.out_tokens))]
        out.new_logprobs = list(req.out_logprobs)
        out.logprobs = list(req.out_logprobs)
        tv, ti = res[idx]
        tv, ti = np.asarray(tv[0]), np.asarray(ti[0])      # (steps, k)
        req.out_topk = [([int(t) for t in ti[i]], [float(v) for v in tv[i]])
                        for i in range(len(req.out_tokens))]
        out.top_logprobs = list(req.out_topk)
    return out
