"""Synthetic open-loop arrival traces for the serving engine.

Benchmark fixtures, not engine machinery — they emit ``ServeRequest``s for
``Engine.submit``/``run``/``stream`` and the benchmarks, so ``serve.engine``
stays a scheduler and the traffic shapes live here. Every trace takes the
sampling knobs (``temperature``/``top_p``/``top_k``/``sample_seed``) so the
same arrival process can be replayed greedy vs sampled: per-request seeds
derive deterministically from ``sample_seed + rid``, which keeps a sampled
trace reproducible run over run (and engine-vs-oneshot, since the seed rides
in the request's ``SamplingParams``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..configs.base import ModelConfig
from .api import SamplingParams, ServeRequest


def attach_modality_inputs(req: ServeRequest, cfg: ModelConfig,
                           rng) -> ServeRequest:
    """Give a request the frontend inputs its family needs (random stand-ins
    for the stub frontends) — shared by the trace generators, the examples,
    and the tests so the shapes can't drift apart."""
    if cfg.family == "vlm":
        req.patches = rng.standard_normal(
            (cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        req.frames = rng.standard_normal(
            (len(req.tokens), cfg.frontend_dim)).astype(np.float32)
    return req


def _params(max_new_tokens: int, temperature: float, top_p: float, top_k: int,
            sample_seed: int, rid: int) -> SamplingParams:
    return SamplingParams(temperature=temperature, top_p=top_p, top_k=top_k,
                          seed=sample_seed + rid,
                          max_new_tokens=int(max_new_tokens))


def synthetic_trace(cfg: ModelConfig, num_requests: int = 40, seed: int = 0,
                    burst_every: int = 10, burst_size: int = 8,
                    light_tokens: int = 5, heavy_tokens: int = 40,
                    heavy_frac: float = 0.15,
                    prompt_lens: tuple = (8, 16),
                    heavy_prompt: Optional[int] = None,
                    temperature: float = 0.0, top_p: float = 1.0,
                    top_k: int = 0, sample_seed: int = 0
                    ) -> list:
    """Bursty heterogeneous arrivals: mostly light requests plus a heavy class
    whose decode length alone blows a chat-style latency budget. Classes:
    0..len(prompt_lens)-1 are light (one per prompt-length bucket); the last
    class is heavy. Prompt lengths come from a tiny bucket set so the engine
    compiles a bounded number of prefill shapes. ``heavy_prompt`` gives the
    heavy class a long prompt of its own (exercises chunked prefill and the
    paged pool's mixed-length admission)."""
    rng = np.random.default_rng(seed)
    reqs = []
    n_light_classes = len(prompt_lens)
    for rid in range(num_requests):
        burst = rid // burst_size
        heavy = rng.random() < heavy_frac
        plen = int(prompt_lens[rid % n_light_classes])
        if heavy and heavy_prompt is not None:
            plen = int(heavy_prompt)
        rclass = n_light_classes if heavy else rid % n_light_classes
        steps = heavy_tokens if heavy else light_tokens + rid % 3
        req = ServeRequest(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            params=_params(steps, temperature, top_p, top_k, sample_seed, rid),
            rclass=rclass,
            arrival=burst * burst_every + int(rng.integers(0, 3)),
        )
        reqs.append(attach_modality_inputs(req, cfg, rng))
    return reqs


def returning_tenant_trace(cfg: ModelConfig, tenants: int = 2,
                           prefix_len: int = 48, suffix_lens: tuple = (4,),
                           burst_size: int = 3, bursts: int = 2,
                           gap: int = 120, decode_lens: tuple = (6,),
                           seed: int = 0, temperature: float = 0.0,
                           top_p: float = 1.0, top_k: int = 0,
                           sample_seed: int = 0) -> list:
    """Returning-tenant traffic: each tenant owns a fixed system prompt and
    sends ``bursts`` bursts of ``burst_size`` requests, with a ``gap`` between
    bursts long enough for the engine to fully drain. Without a persistent
    prefix cache every burst re-prefills the tenant's prefix from scratch
    (refcounts hit zero between bursts); with pinning the second and later
    bursts adopt the tenant's pages out of the pinned cache and prefill only
    their suffixes. Request class = tenant id, so the pin memory learns
    per-tenant adoption value."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, size=prefix_len)
                .astype(np.int32) for _ in range(tenants)]
    reqs, rid = [], 0
    for b in range(bursts):
        for t in range(tenants):
            for i in range(burst_size):
                sfx = rng.integers(
                    0, cfg.vocab_size,
                    size=int(suffix_lens[rid % len(suffix_lens)])
                ).astype(np.int32)
                req = ServeRequest(
                    rid=rid,
                    tokens=np.concatenate([prefixes[t], sfx]),
                    params=_params(decode_lens[rid % len(decode_lens)],
                                   temperature, top_p, top_k, sample_seed, rid),
                    rclass=t,
                    arrival=b * gap + 2 * i,
                )
                reqs.append(attach_modality_inputs(req, cfg, rng))
                rid += 1
    return reqs


def contention_trace(cfg: ModelConfig, num_requests: int = 24,
                     prompt_lens: tuple = (8, 16), hog_prompt: int = 32,
                     light_tokens: int = 4, hog_tokens: int = 24,
                     hog_every: int = 4, arrival_every: int = 1,
                     seed: int = 0, temperature: float = 0.0,
                     top_p: float = 1.0, top_k: int = 0,
                     sample_seed: int = 0) -> list:
    """Page-pool contention: a dense arrival stream mixing short interactive
    requests with a hog class (long prompt, long decode) whose KV growth eats
    pages mid-flight. Run it against an undersized page pool: worst-case
    reservation keeps admission shallow, while preempt-mode admission fills
    slots on current footprint and resolves decode-time exhaustion by evicting
    the lowest-immune-priority slot. Hog requests are class ``len(prompt_lens)``
    (every ``hog_every``-th rid); light classes rotate over prompt buckets."""
    rng = np.random.default_rng(seed)
    n_light = len(prompt_lens)
    reqs = []
    for rid in range(num_requests):
        hog = hog_every > 0 and rid % hog_every == hog_every - 1
        plen = hog_prompt if hog else int(prompt_lens[rid % n_light])
        req = ServeRequest(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            params=_params(hog_tokens if hog else light_tokens + rid % 2,
                           temperature, top_p, top_k, sample_seed, rid),
            rclass=n_light if hog else rid % n_light,
            arrival=rid * arrival_every,
        )
        reqs.append(attach_modality_inputs(req, cfg, rng))
    return reqs


def fleet_trace(cfg: ModelConfig, tenants: int = 3, num_requests: int = 24,
                prefix_len: int = 32, suffix_lens: tuple = (4, 6),
                decode_lens: tuple = (6, 10), hot_tenant: int = 0,
                hot_frac: float = 0.5, burst_every: int = 6,
                burst_size: int = 4, seed: int = 0,
                temperature: float = 0.0, top_p: float = 1.0,
                top_k: int = 0, sample_seed: int = 0) -> list:
    """Multi-tenant fleet traffic for the placement router: ``tenants`` fixed
    system prompts (request class = tenant id, so placement affinity and the
    per-class cost memory both key on the tenant), arrivals in tight bursts,
    and one *hot* tenant contributing ``hot_frac`` of the volume — the
    hot-replica skew that separates placement policies. A router that keeps a
    tenant's traffic where its prompt chains already live prefills only
    suffixes; a router that sprays it re-prefills the prefix on every replica
    and convoys the hot one. Suffix and decode lengths come from tiny bucket
    sets so each replica compiles a bounded number of shapes."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, size=prefix_len)
                .astype(np.int32) for _ in range(tenants)]
    reqs = []
    for rid in range(num_requests):
        hot = rng.random() < hot_frac
        if tenants > 1:
            other = (hot_tenant + 1 + int(rng.integers(0, tenants - 1))) \
                % tenants
        else:
            other = hot_tenant
        t = hot_tenant if hot else other
        sfx = rng.integers(0, cfg.vocab_size,
                           size=int(suffix_lens[rid % len(suffix_lens)])
                           ).astype(np.int32)
        req = ServeRequest(
            rid=rid,
            tokens=np.concatenate([prefixes[t], sfx]),
            params=_params(decode_lens[rid % len(decode_lens)], temperature,
                           top_p, top_k, sample_seed, rid),
            rclass=t,
            arrival=(rid // burst_size) * burst_every + rid % burst_size,
        )
        reqs.append(attach_modality_inputs(req, cfg, rng))
    return reqs


def failover_fleet_trace(cfg: ModelConfig, replicas: int = 3,
                         crash_replica: int = 1, seed: int = 0,
                         rejoin: bool = True, **kw) -> tuple:
    """The fleet trace, fault-laced: ``fleet_trace`` traffic plus a matched
    crash-of-one fault-plan spec (``serve.faults.FaultPlan.parse`` grammar)
    sized to the trace — the crash lands about a third of the way through the
    arrival window (survivors absorb the evacuated work while traffic is
    still arriving, the hard case), and with ``rejoin`` the replica returns
    cold around two thirds of the window — before the tail of arrivals, so
    prefix-affinity traffic visibly rewarms its pinned cache while the run
    is still live. Returns ``(requests, plan_spec)`` — the manual-run
    variant behind ``launch/serve --trace fleet-faults``."""
    reqs = fleet_trace(cfg, seed=seed, **kw)
    horizon = max(r.arrival for r in reqs) if reqs else 0
    crash_at = max(1, horizon // 3)
    r = crash_replica % max(replicas, 1)
    spec = f"crash@{crash_at}:r{r}"
    if rejoin:
        spec += f" rejoin@{max(crash_at + 10, (2 * horizon) // 3)}:r{r}"
    return reqs, spec


def poweroff_fleet_trace(cfg: ModelConfig, seed: int = 0,
                         restart: bool = True, **kw) -> tuple:
    """The fleet trace, power-loss-laced: ``fleet_trace`` traffic plus a
    matched ``poweroff`` fault-plan spec (``serve.faults.FaultPlan.parse``
    grammar) sized to the trace — the lights go out about halfway through
    the arrival window (in-flight decodes, queued work and pending arrivals
    all straddle the loss, the hard case for the journal), and with
    ``restart`` the rebuilt fleet resumes a few ticks later, before the tail
    of arrivals. Returns ``(requests, plan_spec)`` — drive with
    ``serve.durability.run_durable`` (a plain ``Router.run`` would just die
    at the poweroff tick); the manual-run variant behind
    ``launch/serve --trace fleet-poweroff``."""
    reqs = fleet_trace(cfg, seed=seed, **kw)
    horizon = max(r.arrival for r in reqs) if reqs else 0
    off_at = max(1, horizon // 2)
    spec = f"poweroff@{off_at}"
    if restart:
        spec += f" restart@{off_at + 4}"
    return reqs, spec


def agentic_trace(cfg: ModelConfig, sessions: int = 3, turns: int = 4,
                  base_prompt: int = 24, grow_lens: tuple = (6, 10),
                  decode_lens: tuple = (8, 12), turn_gap: int = 12,
                  seed: int = 0, temperature: float = 0.0, top_p: float = 1.0,
                  top_k: int = 0, sample_seed: int = 0) -> list:
    """Agentic multi-turn traffic: each session re-submits its conversation
    every turn with a *grown* prompt — turn ``t``'s prompt is turn ``t-1``'s
    prompt plus a fresh extension (standing in for the appended model answer
    and tool results an agent loop feeds back). Every turn's prompt therefore
    has the previous turn's full prompt as an exact byte prefix, the workload
    where the prefix index + CoW forks pay off hardest, and — decode runs
    being short relative to prompts — the accept-rate-sensitive regime the
    speculative-decoding benchmark drives. Request class = session (mod 3),
    arrivals staggered so turns of different sessions interleave; rids are
    sequential in submission order so seeded sampling replays exactly."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, size=base_prompt)
               .astype(np.int32) for _ in range(sessions)]
    reqs, rid = [], 0
    for t in range(turns):
        for s in range(sessions):
            if t > 0:
                ext = rng.integers(
                    0, cfg.vocab_size,
                    size=int(grow_lens[(t + s) % len(grow_lens)])
                ).astype(np.int32)
                prompts[s] = np.concatenate([prompts[s], ext])
            req = ServeRequest(
                rid=rid,
                tokens=prompts[s].copy(),
                params=_params(decode_lens[rid % len(decode_lens)],
                               temperature, top_p, top_k, sample_seed, rid),
                rclass=s % 3,
                arrival=t * turn_gap + 2 * s,
            )
            reqs.append(attach_modality_inputs(req, cfg, rng))
            rid += 1
    return reqs


def shared_prefix_trace(cfg: ModelConfig, num_requests: int = 32,
                        num_prefixes: int = 2, prefix_len: int = 32,
                        suffix_lens: tuple = (4, 8),
                        decode_lens: tuple = (6, 10),
                        arrival_every: int = 2, seed: int = 0,
                        temperature: float = 0.0, top_p: float = 1.0,
                        top_k: int = 0, sample_seed: int = 0
                        ) -> list:
    """System-prompt traffic: ``num_prefixes`` fixed prefixes, each followed by
    a per-request random suffix — the workload where prefix page sharing turns
    O(total tokens) of prefill + KV into O(unique tokens). Request class =
    prefix id (the immune memory then tracks cost per system prompt). Suffix
    and decode lengths come from tiny bucket sets so the engine compiles a
    bounded number of shapes."""
    rng = np.random.default_rng(seed)
    prefixes = [rng.integers(0, cfg.vocab_size, size=prefix_len)
                .astype(np.int32) for _ in range(num_prefixes)]
    reqs = []
    for rid in range(num_requests):
        pfx = prefixes[rid % num_prefixes]
        sfx = rng.integers(0, cfg.vocab_size,
                           size=int(suffix_lens[rid % len(suffix_lens)])
                           ).astype(np.int32)
        req = ServeRequest(
            rid=rid,
            tokens=np.concatenate([pfx, sfx]),
            params=_params(decode_lens[rid % len(decode_lens)], temperature,
                           top_p, top_k, sample_seed, rid),
            rclass=rid % num_prefixes,
            arrival=rid * arrival_every,
        )
        reqs.append(attach_modality_inputs(req, cfg, rng))
    return reqs
