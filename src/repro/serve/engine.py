"""Continuous-batching serving engine governed by the paper's immune primitives,
over a **paged KV cache** with **chunked prefill**.

``serve.decode.generate`` serves a *fixed* batch: every prompt prefills together
and every sequence decodes in lockstep until the longest finishes. Real traffic
is an open-loop arrival process, so the engine keeps a fixed pool of decode
**slots** and admits requests mid-stream; finished sequences retire and their
slot is reused. All slot state is arrays (per-slot cache position, last token,
active mask), so one compiled decode step serves every tick regardless of
occupancy.

Memory plane — the page-table layout:

  * Each full-attention layer's K/V is a physical **page pool**
    ``(num_pages, page_size, Hkv, D)`` (stacked over depth by the layer scan).
    Page 0 is the null/trash page: never allocated, absorbs the writes of
    inactive decode lanes, read only masked.
  * A host-side block table (``serve.paging.PageAllocator``) maps each slot's
    logical pages to physical ones; the device sees the dense
    ``(num_slots, max_pages_per_slot)`` int32 table each tick. With
    ``max_pages_per_slot = max_cache // page_size`` the gathered K/V length is
    exactly ``max_cache``, so the paged decode is bitwise-identical to the
    dense slot-row layout (null-page padding is masked to exact zeros).
  * Admission charges pages under one of two disciplines
    (``EngineConfig.admission_mode``). ``"reserve"`` (legacy) promises a
    request's worst-case page count (``ceil((prompt + decode budget) /
    page_size)``) up front, so decode can never stall — paid for in admission
    pessimism. ``"preempt"`` (default) admits on *current* pages (the padded
    prompt tail only); decode growth acquires pages on demand, and when the
    pool runs dry the engine **preempts** the lowest-immune-priority resident
    (anergic classes first, then over-budget, then highest remembered cost —
    the paper's suppression signal as victim selection): its pages release,
    it re-queues, and on re-admission it re-prefills its original prompt and
    *replays* its recorded tokens through decode (same lane keys, same
    fold_in indices), so a preempted-then-resumed request is token-bitwise-
    identical to an unpreempted run. Either way pages are appended lazily as
    prefill chunks land and decode crosses page boundaries; retirement
    returns pages with no zeroing or row compaction. Recurrent states and
    sliding-window ring buffers are O(1)/O(window) per slot and stay
    slot-indexed — only full attention carries sequence-length paging.
  * **Pinned prefix cache** (``EngineConfig.pin_pages > 0``): the allocator
    keeps full prompt-page chains resident after their refcounts hit zero,
    charged to a pin budget with immune-memory-weighted LRU eviction (the
    per-class adoption-value EMA scores which chains stay hot). A returning
    tenant minutes later adopts the pinned chain exactly like a live shared
    one — its prefill is O(unique tokens) across idle gaps, not just within
    a burst.
  * **Prefix sharing** (``EngineConfig.prefix_sharing``): the allocator keeps
    a refcounted index of full prompt pages keyed by their token content.
    Admission walks a new prompt through it and *adopts* every hit —
    refcount++ on a resident physical page instead of reserving and
    re-prefilling it — so a thousand requests behind one system prompt hold
    ONE copy of its KV and only pay prefill for their unshared tails:
    O(unique tokens), not O(total), in both compute and pages. This is the
    paper's immune memory applied to KV state — work the population has
    already seen is recognized and not re-paid. A partial last-page hit is
    adopted too and **copy-on-write forked** (fresh page + on-device copy of
    the shared entries) before the slot's first write into it; shared full
    pages are never written (decode writes land past the prompt), so only the
    fork ever copies. Sharing is gated to configs where K/V is a pure function
    of the token prefix (text-only attention/dropless-MoE stacks with chunked
    prefill); recurrent state, frontend-conditioned and one-shot-prefill
    families never share. Admission charges only the *unshared* pages against
    ``available()``, so a prefix-hot request is admissible even when the pool
    could not hold its worst case from the free list alone.

Compute plane — chunked prefill (``EngineConfig.prefill_chunk > 0``): long
prompts are sliced into decode-tick-sized chunks written straight into the
slot's pages, one chunk per engine tick, interleaved with the running decodes —
a long prefill no longer stalls occupied slots, and the engine compiles ONE
chunk shape instead of one prefill shape per prompt length. Chunking applies
where it is bitwise-exact (attention stacks; MoE at dropless expert capacity;
SSM via state-resume when lengths align to ``ssm_chunk``); VLM prefix-LM,
finite-capacity MoE, and RG-LRU hybrids fall back to one-shot prefill. With
``prefill_streams > 1`` (attention stacks only), up to that many in-flight
prefill jobs advance per tick in ONE batched compiled call — concurrent long
prompts no longer serialize chunk-per-tick behind each other. Decode runs the
paged attention through ``EngineConfig.attn_backend``: the XLA gather
fallback, or the ``kernels.paged_attention`` Pallas kernel ("pallas" on TPU,
"pallas_interpret" anywhere) whose scalar-prefetch block-table index maps turn
the gather into the DMA schedule itself.

Admission is the immune loop applied to serving, per the anticipation argument
of Boulmier et al. (PAPERS.md) — schedule on *remembered* cost, not
instantaneous load:

  * ``ImmuneMemory``      — EMA of per-request-class decode cost (slot-ticks);
                            admission orders candidates by remembered cost, so
                            a class's history, not the current queue snapshot,
                            decides who gets a slot under pressure.
  * ``TwoStageRegulator`` — admission-burst throttle: a burst admits at full
                            speed (fast response), the suppressor population
                            then builds and pauses follow-on admissions
                            (delayed negative feedback), damping convoys.
  * ``AnergyGate``        — request classes that repeatedly blow their latency
                            budget without co-stimulation (in-budget
                            completions) become anergic and are shed (left in
                            the queue, not admitted); an IL-2-like signal
                            revives them when queue pressure drops.

A request whose prompt can never fit a slot is rejected at ``submit`` (counted
in ``stats()['rejected']``, against goodput) instead of raising; a request that
fits but finds no free pages is simply deferred in the queue until pages free
up — out-of-pages backpressure, not an error.

Requests arrive as ``serve.api.ServeRequest`` — prompt + ``SamplingParams``
(temperature/top-p/top-k/seed, token budget, stop ids) + scheduling metadata —
and progress leaves as ``serve.api.RequestOutput`` deltas from ``stream()``.
Sampling runs *inside* the one compiled decode step: per-slot lane arrays
(``models.model.SamplingSpec``) ride next to ``last``/``active``, each lane's
key folds with its slot's emitted-token count, and ``model.sample_tokens``
applies the masked top-k/top-p draw on the logits lane — the same lane math
(and key discipline) as one-shot ``serve.api.generate``, so a seeded request
emits identical tokens on either backend and temperature-0 lanes stay bitwise
argmax. Retirement is per-request: token budget or any of the request's stop
ids (``Engine._finished`` records the ``finish_reason``), and pages free the
same tick.

The FIFO policy (``EngineConfig(policy="fifo")``) is the baseline the
benchmark compares against; ``page_size == max_cache`` degenerates to the
fixed-row engine (one page per slot, reserved whole at admission) for
equal-memory comparisons.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from dataclasses import dataclass
from functools import partial
from typing import Iterator, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import immune
from ..models import model, transformer
from . import groups
from . import spec as specdec
from .api import (RequestOutput, SamplingParams, ServeRequest,  # noqa: F401
                  spec_for)
from .decode import greedy, null_spec
from .paging import OutOfPages, PageAllocator, pages_for

Array = jax.Array


class EngineConfig(NamedTuple):
    num_slots: int = 4
    max_cache: int = 96               # per-slot logical KV capacity (tokens)
    policy: str = "immune"            # "immune" | "fifo"
    num_classes: int = 4
    # Unit discipline: latency_budget is engine TICKS and is only ever compared
    # against tick latencies (finish_tick - arrival); a per-request
    # ServeRequest.deadline is wall-clock SECONDS and is only ever compared
    # against wall-clock latencies (finish_time - submit_time). One unit per
    # comparison — see Engine._slo.
    latency_budget: float = 32.0      # ticks; beyond this a completion "blew" SLO
    mem_decay: float = 0.8            # cost-memory EMA decay
    reg_threshold: float = 2.0        # admission pauses while response exceeds this
    shed_level: float = 0.5           # anergy level above which a class is shed
    low_pressure: float = 0.5         # queue_len < low_pressure*num_slots -> IL-2
    anergy_onset: float = 0.34
    anergy_revival: float = 0.3
    # -- paged KV plane ------------------------------------------------------
    page_size: int = 16               # tokens per physical page
    num_pages: Optional[int] = None   # pool size incl. the null page; None ->
    #                                   fully provisioned (slots*maxp + 1),
    #                                   admission-equivalent to fixed rows
    prefill_chunk: int = 0            # >0: chunked prefill, one chunk per tick
    prefix_sharing: bool = True       # refcounted prompt-prefix page sharing
    attn_backend: str = "xla"         # "xla" | "pallas" | "pallas_interpret"
    prefill_streams: int = 1          # >1: batch that many prefill jobs/tick
    capture_logits: bool = False      # record per-token logits rows on each
    #                                   request (the logits parity oracle)
    # -- KV memory hierarchy -------------------------------------------------
    admission_mode: str = "preempt"   # "preempt": admit on current pages and
    #                                   evict the lowest-immune-priority slot
    #                                   when decode would stall; "reserve":
    #                                   legacy worst-case page reservation
    pin_pages: int = 0                # persistent prefix-cache budget: full
    #                                   prompt-page chains survive refcount
    #                                   zero as pinned entries (0 = off)
    # -- self-speculative decoding -------------------------------------------
    spec_decode: int = 0              # k: draft tokens proposed per spec tick
    #                                   (0 = off). Spec ticks run only on
    #                                   all-greedy resident batches with no
    #                                   penalties/logprobs; emitted tokens are
    #                                   bitwise the non-speculative stream's.
    spec_draft_layers: int = 0        # draft depth: leading layer repetitions
    #                                   of the SAME weights the draft pass
    #                                   runs (truncated-depth early exit);
    #                                   must be in (0, num_layers)


@dataclass
class _PrefillJob:
    """An in-flight chunked prefill: chunks land tick by tick while the other
    slots keep decoding; the slot activates when the last chunk lands. ``p0``
    starts past the shared prefix when admission adopted resident pages —
    only the unshared tail is ever computed."""
    req: ServeRequest
    slot: int
    p0: int          # next chunk's first absolute position
    total: int       # padded prompt end (p0 grid aligned to prefill_chunk)
    length: int      # true prompt length (incl. any frontend prefix)
    share: bool = False   # register this prompt's full pages on completion


# ---------------------------------------------------------------------------
# jitted slot-pool kernels — shared across Engine instances via jit's cache
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("cfg", "max_cache"))
def _prefill_one(params, cfg: ModelConfig, prompts: dict, max_cache: int,
                 router_bias):
    """Prefill a batch-of-1 prompt into a fresh dense cache; returns
    (last-position logits, cache). Identical math to the first stage of
    ``decode.generate`` — the parity anchor for the one-shot admission path;
    the logits seed decoding through ``_seed_token``."""
    cache = model.init_cache(cfg, 1, max_cache)
    logits, cache = model.prefill(params, cfg, prompts, cache,
                                  router_bias=router_bias)
    return logits, cache


@partial(jax.jit, static_argnames=("do_sample",))
def _seed_token(logits, spec, do_sample: bool):
    """First emitted token from a prompt's last-position logits: exact argmax
    on the greedy path, else the request's sampling lane at fold index 0 —
    the same draw one-shot ``decode.generate`` takes for its first token."""
    return model.sample_tokens(logits, spec, 0) if do_sample \
        else greedy(logits)


@jax.jit
def _chosen_lp(logits, tok):
    """Chosen-token logprob of a seed token (the per-request admission path;
    decoded tokens get theirs inside the compiled decode tick)."""
    return model.chosen_logprob(logits, tok)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 5))
def _splice(pool, one, slot, table_row, first, last, active, cfg: ModelConfig):
    """Insert a one-shot prefilled batch-of-1 cache + its first token into
    ``slot`` of the paged pool (K/V rows scattered to the slot's pages)."""
    pool = model.insert_slot_cache_paged(pool, one, cfg, slot, table_row)
    return pool, last.at[slot].set(first[0]), active.at[slot].set(True)


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(3,))
def _prefill_chunk(params, cfg: ModelConfig, chunk: dict, pool, table_row, p0,
                   last_idx, slot, router_bias):
    """Land one prefill chunk in the slot's pages; returns (logits of the
    chunk's last real position, pool). One compiled shape per config; the
    logits only matter on the final chunk, where they seed decoding."""
    logits, pool = model.prefill_chunk(params, cfg, chunk, pool, table_row, p0,
                                       last_idx, slot, router_bias=router_bias)
    return logits, pool


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(3,))
def _prefill_chunks(params, cfg: ModelConfig, chunk: dict, pool, tables, p0s,
                    last_idxs, router_bias):
    """Land one chunk of up to ``prefill_streams`` concurrent prefill jobs in
    ONE compiled call (attention stacks only); lanes beyond the live job count
    are padding with all-null tables. Returns ((J, 1, V) logits, pool)."""
    logits, pool = model.prefill_chunk_multi(params, cfg, chunk, pool, tables,
                                             p0s, last_idxs,
                                             router_bias=router_bias)
    return logits, pool


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def _copy_page(pool, src, dst, cfg: ModelConfig):
    """Copy-on-write fork: duplicate physical page ``src`` into ``dst`` across
    every paged layer before the forking slot's first write into it."""
    return model.copy_page_paged(pool, cfg, src, dst)


@partial(jax.jit, donate_argnums=(0, 1))
def _activate(pool, last, active, slot, first, length):
    """Final chunk landed: set the slot's position, first token, active bit."""
    return ({"layers": pool["layers"], "pos": pool["pos"].at[slot].set(length)},
            last.at[slot].set(first[0]), active.at[slot].set(True))


@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0,))
def _release(pool, active, slot, cfg: ModelConfig):
    """Retire ``slot``: zero its slot-row (recurrent/ring) state and position;
    its physical pages just return to the host free list, unzeroed."""
    return (model.release_slot_cache_paged(pool, cfg, slot),
            active.at[slot].set(False))


# pool and last are donated: the engine rebinds both from the return value each
# tick, and without donation every decoded token would pay a fresh copy of the
# whole pooled KV cache (the scan carry in decode._decode_loop gets this free)
@partial(jax.jit,
         static_argnames=("cfg", "attn_backend", "do_sample", "return_logits",
                          "return_logprobs", "use_penalties", "return_topk"),
         donate_argnums=(2, 3))
def _decode_tick(params, cfg: ModelConfig, pool, last, active, table,
                 router_bias, frames, spec, steps_done, pen_counts=None,
                 attn_backend="xla", do_sample=False, return_logits=False,
                 return_logprobs=False, use_penalties=False,
                 return_topk: int = 0):
    """One token for every slot (occupied or not) — the single compiled decode
    step. Inactive slots advance neither position nor state; their lane
    computes a garbage token that the host discards (paged K/V writes of
    inactive lanes are routed to the null page, slot-row caches are frozen),
    which keeps the step shape independent of occupancy AND keeps garbage
    lanes from dirtying pages a mid-flight chunked prefill already owns.
    ``attn_backend`` selects the paged attention compute (XLA gather vs the
    Pallas block-table kernel). With ``do_sample``, per-slot sampling runs on
    the logits lane in this same compiled step: ``spec`` carries each slot's
    key/temperature/top-k/top-p row and ``steps_done`` its emitted-token
    count (the fold_in index), so a lane's draw depends only on its own
    request — never on what shares the pool. The raw logits are returned for
    the capture-logits parity oracle."""
    batch = {"token": last}
    if cfg.family == "audio":
        batch["frame"] = frames
    logits, new_pool = model.decode_step(params, cfg, batch, pool,
                                         router_bias=router_bias,
                                         table=table, active=active,
                                         attn_backend=attn_backend)
    # repetition/presence/frequency penalties ride the sampling lane: a
    # per-lane where in model.penalize_logits keeps penalty-free lanes bitwise
    # on the unpenalized path, and greedy-with-penalties is the temperature-0
    # sampling lane (argmax of the penalized logits)
    nxt = model.sample_tokens(logits, spec, steps_done,
                              counts=pen_counts if use_penalties else None) \
        if do_sample else greedy(logits)             # (S, 1)
    pos = jnp.where(active, new_pool["pos"], pool["pos"])
    last = jnp.where(active[:, None], nxt, last)
    # silent-corruption guard: a NaN/Inf anywhere in a lane's logits means its
    # KV or activations are poisoned (bad page, bit flip, kernel bug) and the
    # sampled token is garbage — flag the lane so the host retires it as
    # "corrupted" instead of streaming the garbage on. A (S,)-bool reduction
    # over the logits already resident is noise next to the matmul that
    # produced them, so the guard is always on.
    ok = jnp.isfinite(logits).all(axis=(1, 2))
    # the (S, 1, V) logits are a jit output only when the parity oracle wants
    # them — otherwise returning them would materialize a vocab-sized buffer
    # per decoded token just for the host to drop. Chosen-token logprobs ride
    # in-step on the logits lane already resident (no extra vocab pass on the
    # host side) when any resident request asked for them.
    # top-k alternative logprobs ride in-step too (partial sort of the raw
    # log-softmax lane — the host slices each request's own k out of the
    # batch-wide max-k rows; a shorter prefix of a longer top_k is identical)
    topk = None
    if return_topk:
        lpf = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
        topk = jax.lax.top_k(lpf, return_topk)       # ((S, k) vals, (S, k) ids)
    return (nxt, last, {"layers": new_pool["layers"], "pos": pos}, ok,
            logits if return_logits else None,
            model.chosen_logprob(logits, nxt) if return_logprobs else None,
            topk)


@partial(jax.jit, static_argnames=("k",))
def _topk_lp(logits, k: int):
    """Top-k alternative logprobs of a prefill's last-position logits (the
    seed token's row — decoded rows get theirs inside the compiled tick)."""
    lp = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
    return jax.lax.top_k(lp, k)


# ---------------------------------------------------------------------------
# immune admission controller
# ---------------------------------------------------------------------------
class ImmuneAdmission:
    """Host-side admission controller over the three immune primitives.

    Per tick: completions feed the cost memory and the anergy
    stimulus/co-stimulus counters; ``end_tick`` advances the regulator (with the
    tick's admissions as stimulus) and the anergy gate (with IL-2 flowing when
    queue pressure is low)."""

    def __init__(self, ecfg: EngineConfig):
        self.ecfg = ecfg
        c = ecfg.num_classes
        self.memory = immune.ImmuneMemory.create((c,), decay=ecfg.mem_decay)
        self.regulator = immune.TwoStageRegulator.create()
        self.reg_state = self.regulator.init(())
        self.gate = immune.AnergyGate.create(onset=ecfg.anergy_onset,
                                             revival=ecfg.anergy_revival)
        self.anergy = self.gate.init((c,))
        self._blown = np.zeros(c, np.float32)
        self._ok = np.zeros(c, np.float32)

    def remembered_cost(self, rclass: int) -> float:
        return float(self.memory.value[rclass])

    def observe_completion(self, rclass: int, cost: float, latency: float,
                           budget: Optional[float] = None):
        # per-class EMA: observing `value` for the untouched classes leaves them
        # unchanged under ImmuneMemory's decay*v + (1-decay)*obs update
        self.memory = self.memory.update(
            self.memory.value.at[rclass].set(cost))
        if budget is None:
            budget = self.ecfg.latency_budget
        if latency > budget:
            self._blown[rclass] += 1.0
        else:
            self._ok[rclass] += 1.0

    def admissible(self, rclass: int) -> bool:
        return float(self.anergy.level[rclass]) <= self.ecfg.shed_level

    def throttled(self) -> bool:
        return float(self.reg_state.response) > self.ecfg.reg_threshold

    def degrade(self, classes, severity: float):
        """Fleet capacity loss as an immune stress signal (graceful
        degradation): drive the anergy gate toward shedding ``classes`` by
        applying antigen without co-stimulation, scaled by ``severity``
        (the router's view of how much of the fleet is dead). Called by the
        fleet router each tick a replica is down, so low-priority classes
        shed on the survivors before interactive traffic browns out; once
        capacity returns the stimulus stops and IL-2 revives the classes in
        the next quiet period — the same revival path as ordinary anergy."""
        c = self.ecfg.num_classes
        stim = np.zeros(c, np.float32)
        for k in classes:
            if 0 <= k < c:
                stim[k] = min(max(float(severity), 0.0), 1.0)
        self.anergy = self.gate.step(
            self.anergy, stimulus=jnp.asarray(stim),
            costimulus=jnp.zeros(c, jnp.float32), il2=0.0)

    def end_tick(self, admitted: int, queue_len: int,
                 queued_demand: np.ndarray, predicted_cost: np.ndarray):
        """Advance the regulator and anergy gate one tick.

        Anergy stimulus is anticipatory: a class with queued demand whose
        predicted cost already exceeds the latency budget *will* blow its SLO —
        that is antigen without co-stimulation, and waiting for the completions
        to prove it would let the convoy form first. In-budget completions are
        the co-stimulation; IL-2 flows when queue pressure drops, reviving shed
        classes so they are served in quiet periods."""
        stim = jnp.asarray(admitted / max(self.ecfg.num_slots, 1), jnp.float32)
        self.reg_state = self.regulator.step(self.reg_state, stim)
        il2 = 1.0 if queue_len < self.ecfg.low_pressure * self.ecfg.num_slots \
            else 0.0
        will_blow = (queued_demand > 0) & \
            (predicted_cost > self.ecfg.latency_budget)
        self.anergy = self.gate.step(
            self.anergy,
            stimulus=jnp.asarray((self._blown > 0) | will_blow, jnp.float32),
            costimulus=jnp.asarray(self._ok > 0, jnp.float32),
            il2=il2)
        self._blown[:] = 0.0
        self._ok[:] = 0.0

    # -- durability ----------------------------------------------------------
    def export_state(self) -> dict:
        """JSON-able snapshot of the learned immune state: the per-class
        cost-memory EMA, the regulator populations, the anergy levels, and
        the tick-local SLO counters. Configuration (decays, thresholds) is
        NOT exported — it comes from the EngineConfig at restore."""
        def ls(tree):
            return [np.asarray(x).tolist() for x in jax.tree.leaves(tree)]
        return {"memory": np.asarray(self.memory.value).tolist(),
                "regulator": ls(self.reg_state),
                "anergy": ls(self.anergy),
                "blown": self._blown.tolist(), "ok": self._ok.tolist()}

    def import_state(self, d: dict) -> None:
        """Restore :meth:`export_state` output into this controller — the
        memory resumes warm instead of re-learning every class from zero."""
        def put(tree, vals):
            leaves, treedef = jax.tree.flatten(tree)
            return jax.tree.unflatten(treedef, [
                jnp.asarray(np.asarray(v, np.asarray(l).dtype).reshape(
                    np.shape(l))) for l, v in zip(leaves, vals)])
        self.memory = self.memory._replace(
            value=jnp.asarray(d["memory"], self.memory.value.dtype))
        self.reg_state = put(self.reg_state, d["regulator"])
        self.anergy = put(self.anergy, d["anergy"])
        self._blown = np.asarray(d["blown"], np.float32)
        self._ok = np.asarray(d["ok"], np.float32)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class Engine:
    """Continuous-batching decode over a paged slot pool with queue admission."""

    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 router_bias: Optional[Array] = None):
        if ecfg.max_cache % ecfg.page_size:
            raise ValueError(f"max_cache {ecfg.max_cache} must be a multiple "
                             f"of page_size {ecfg.page_size}")
        if ecfg.prefill_chunk and ecfg.max_cache % ecfg.prefill_chunk:
            raise ValueError(f"max_cache {ecfg.max_cache} must be a multiple "
                             f"of prefill_chunk {ecfg.prefill_chunk}")
        if ecfg.attn_backend not in ("xla", "pallas", "pallas_interpret"):
            raise ValueError(f"unknown attn_backend {ecfg.attn_backend!r}")
        if ecfg.admission_mode not in ("preempt", "reserve"):
            raise ValueError(f"unknown admission_mode {ecfg.admission_mode!r}")
        if ecfg.spec_decode < 0:
            raise ValueError(f"spec_decode must be >= 0, got {ecfg.spec_decode}")
        if ecfg.spec_decode and not 0 < ecfg.spec_draft_layers < cfg.num_layers:
            raise ValueError(
                f"spec_draft_layers must be in (0, {cfg.num_layers}), got "
                f"{ecfg.spec_draft_layers}")
        self.params, self.cfg, self.ecfg = params, cfg, ecfg
        self.router_bias = router_bias
        # MoE: the decode tick runs every slot, occupied or not, and expert
        # capacity is contended across whatever shares the batch — a garbage
        # lane from an empty slot must never displace a real request's token.
        # Bump the decode-path capacity so the (tiny: num_slots * k) token set
        # is dropless by construction. Prefill keeps the configured capacity:
        # it is a batch-of-1 call, bitwise-identical to one-shot generate's.
        self.cfg_decode = cfg if not cfg.num_experts else dataclasses.replace(
            cfg, capacity_factor=float(max(cfg.num_experts,
                                           cfg.capacity_factor)))
        s = ecfg.num_slots
        self.maxp = ecfg.max_cache // ecfg.page_size
        num_pages = ecfg.num_pages if ecfg.num_pages is not None \
            else s * self.maxp + 1
        self.alloc = PageAllocator(
            num_pages, ecfg.page_size, s, self.maxp,
            share_prefix=ecfg.prefix_sharing, pin_pages=ecfg.pin_pages,
            num_classes=ecfg.num_classes, pin_decay=ecfg.mem_decay,
            require_reservation=(ecfg.admission_mode == "reserve"))
        kinds = set(transformer.layer_kinds(cfg))
        # prefix sharing is only sound where a position's K/V is a pure
        # function of the token prefix AND the unshared tail can run through
        # chunked prefill: text-only attention/dropless-MoE stacks
        self._share_ok = (ecfg.prefix_sharing and ecfg.prefill_chunk > 0
                          and kinds <= {"attn", "moe"}
                          and not cfg.frontend_dim and not cfg.frontend_tokens)
        # batched prefill streams need lanes with no slot-row state and no
        # per-position frontend inputs — same attention-stack gate
        self._multi_prefill = (ecfg.prefill_streams > 1
                               and kinds <= {"attn", "moe"}
                               and cfg.family not in ("audio", "vlm"))
        # self-speculative decoding: needs the k-position verify path (pure
        # attention/dropless-MoE stacks, no frontend inputs, no slot-row
        # state) and a single scan segment for the truncated-depth draft
        # slice. A router bias rides along (verify routes with exactly the
        # plain tick's bias). The per-tick gate additionally requires every
        # resident greedy with no penalties/logprobs — fold_in key and
        # penalty-count discipline are per-emitted-token, which a multi-token
        # tick cannot honor.
        self._spec_ok = (ecfg.spec_decode > 0
                         and 0 < ecfg.spec_draft_layers < cfg.num_layers
                         and kinds <= {"attn", "moe"}
                         and len(transformer.segments(cfg)) == 1
                         and not cfg.frontend_dim and not cfg.frontend_tokens
                         and cfg.family not in ("audio", "vlm"))
        self.pool = model.init_slot_cache_paged(cfg, s, ecfg.max_cache,
                                                num_pages, ecfg.page_size)
        self.last = jnp.zeros((s, 1), jnp.int32)
        self.active = jnp.zeros((s,), bool)
        self.frames = (jnp.zeros((s, 1, cfg.frontend_dim), jnp.float32)
                       if cfg.family == "audio" else None)
        self.slots: list[Optional[ServeRequest]] = [None] * s
        self.jobs: deque[_PrefillJob] = deque()
        self.pos_host = np.zeros(s, np.int64)      # per-slot next write index
        self.active_host = np.zeros(s, bool)
        # per-slot tokens computed since (re-)admission, seed included — the
        # decode fold_in index. Diverges from len(out_tokens) only while a
        # preempted request replays its recorded history through decode.
        self.emitted = np.zeros(s, np.int64)
        # per-slot sampling lanes (SamplingSpec rows); free slots hold the
        # greedy row (temperature 0), so their garbage lane costs argmax only
        self.samp_keys = np.zeros((s, 2), np.uint32)
        self.samp_temp = np.zeros((s,), np.float32)
        self.samp_topk = np.zeros((s,), np.int32)
        self.samp_topp = np.ones((s,), np.float32)
        self.samp_rep = np.ones((s,), np.float32)   # 1.0 = penalty off
        self.samp_pres = np.zeros((s,), np.float32)
        self.samp_freq = np.zeros((s,), np.float32)
        # per-slot emitted-token counts over the vocab — the penalty state.
        # Rebuilt from zero at (re-)admission and advanced token by token on
        # the host (replay re-walks the identical sequence, so a resumed
        # request's counts at each fold index equal its first run's)
        self.tok_counts = np.zeros((s, cfg.vocab_size), np.int32)
        self._spec_cache = None            # device copy of the samp_* rows
        self._null_spec = null_spec(s)     # all-greedy lanes, built once
        self.queue: deque[ServeRequest] = deque()
        self.tick = 0
        self.completed: list[ServeRequest] = []
        self.shed: list[ServeRequest] = []    # admission-refused (anergic class)
        self.rejected: list[ServeRequest] = []  # can never fit a slot (submit)
        self.corrupted: list[ServeRequest] = []  # non-finite decode logits
        # refusal high-water marks for stream(): persistent, so refusals that
        # predate the stream are still reported (once) and a second stream()
        # call does not re-report earlier ones
        self._reported_rejected = 0
        self._reported_shed = 0
        self._reported_corrupted = 0
        self.admission = ImmuneAdmission(ecfg) if ecfg.policy == "immune" \
            else None
        self.mid_stream_admissions = 0     # admissions while other slots decode
        self.unsubmitted = 0               # run() arrivals never reached
        self.concurrency_hw = 0            # max simultaneously occupied slots
        self.chunked_prefill_chunks = 0    # chunk lanes landed
        self.prefill_batch_calls = 0       # batched multi-job prefill dispatches
        self.shared_pages_adopted = 0      # prefix-index hits turned refcount++
        self.prefill_positions_skipped = 0  # prompt positions never recomputed
        self.sharable_prompt_pages = 0     # hit-rate denominator (sharable reqs)
        self.preemptions = 0               # slot evictions under page pressure
        self.preempted_rids: set = set()   # distinct requests ever preempted
        self.replayed_tokens = 0           # recorded tokens re-derived by decode
        self.nowrite_adoptions = 0         # full-last-page adoptions (no fork)
        self.prefill_tokens = 0            # prompt positions actually computed
        # self-speculative decode telemetry
        self.spec_ticks = 0                # fused draft+verify ticks run
        self.spec_drafted = 0              # draft tokens proposed (k per lane)
        self.spec_accepted = 0             # draft tokens accepted and emitted
        self.spec_emitted = 0              # tokens emitted by spec ticks
        #                                    (accepted prefix + bonus)
        # slot groups: parents submitted directly to this engine assemble
        # their joint output here; member requests of router-held parents
        # pass through unregistered (the router owns their book)
        self.group_book = groups.GroupBook()
        self.groups_submitted = 0
        self._group_ready: set = set()     # group ids whose shared prompt
        #                                    pages are registered (lane-0
        #                                    prefill landed) — sibling lanes
        #                                    defer admission until then, so
        #                                    the prompt's pages are charged
        #                                    once and adopted n-1 times
        self._admitted_this_tick = 0
        self._decoding_before_admit = False

    # -- queue ---------------------------------------------------------------
    def submit(self, req: ServeRequest):
        """Queue a request. A prompt+decode budget that can never fit a slot is
        *rejected* (recorded, counted against goodput) rather than raised: an
        open-loop server sheds what it cannot serve, it does not crash."""
        if self.admission is not None and not 0 <= req.rclass < \
                self.ecfg.num_classes:
            raise ValueError(f"request {req.rid}: rclass {req.rclass} outside "
                             f"[0, {self.ecfg.num_classes})")
        if req.submit_time < 0:
            # first submission only: a request re-placed on a survivor after a
            # replica crash keeps its original clock, so wall latency (and a
            # wall-clock deadline) spans crash + replay, not just the last leg
            req.submit_time = time.perf_counter()
        if req.params.group_size > 1 and req.group < 0:
            # slot-group parent: expand into member lanes (identical prompt,
            # per-lane seeds) and queue them; the parent itself never holds a
            # slot. Fit checks are per-member and members are identical, so
            # one probe decides the whole group jointly — a group is admitted
            # whole or rejected whole, never half-scheduled.
            members = groups.expand(req)
            probe = members[0]
            need = len(probe.tokens) + self.cfg.frontend_tokens \
                + probe.max_new_tokens
            if need > self.ecfg.max_cache \
                    or self._need_pages(probe) > self.alloc.usable_pages:
                req.finish_reason = "rejected"
                self.rejected.append(req)
                return
            self.groups_submitted += 1
            self.group_book.register(req)
            for m in members:
                m.submit_time = req.submit_time
                self.queue.append(m)
            return
        need = len(req.tokens) + self.cfg.frontend_tokens + req.max_new_tokens
        if need > self.ecfg.max_cache \
                or self._need_pages(req) > self.alloc.usable_pages:
            req.finish_reason = "rejected"  # terminal on the request itself,
            self.rejected.append(req)       # so a journal scan sees the same
            return                          # reason the stream reports
        self.queue.append(req)

    # -- sampling lanes ------------------------------------------------------
    def _pool_spec(self) -> model.SamplingSpec:
        """The slot pool's per-lane sampling rows. Lanes only change at
        admission (``_seed_slot``) and retirement (``_retire``), so the device
        arrays are cached between those events rather than re-uploaded per
        decoded token."""
        if self._spec_cache is None:
            self._spec_cache = model.SamplingSpec(
                keys=jnp.asarray(self.samp_keys),
                temperature=jnp.asarray(self.samp_temp),
                top_k=jnp.asarray(self.samp_topk),
                top_p=jnp.asarray(self.samp_topp),
                rep_penalty=jnp.asarray(self.samp_rep),
                pres_penalty=jnp.asarray(self.samp_pres),
                freq_penalty=jnp.asarray(self.samp_freq))
        return self._spec_cache

    def _seed_slot(self, req: ServeRequest, logits) -> Array:
        """Sample/argmax the request's first token from its prefill logits and
        bind its sampling lane to the slot (capture the logits row if the
        parity oracle asked for it). ``api.spec_for`` builds the batch-of-1
        lane, so the seed-token draw is bitwise the one-shot facade's."""
        self.samp_keys[req.slot] = req.params.key()
        self.samp_temp[req.slot] = req.params.temperature
        self.samp_topk[req.slot] = req.params.top_k
        self.samp_topp[req.slot] = req.params.top_p
        self.samp_rep[req.slot] = req.params.repetition_penalty
        self.samp_pres[req.slot] = req.params.presence_penalty
        self.samp_freq[req.slot] = req.params.frequency_penalty
        self.tok_counts[req.slot] = 0      # penalty counts rebuild from zero
        #                                    (replay re-walks the same tokens)
        self._spec_cache = None
        if self.ecfg.capture_logits and not req.out_tokens:
            req.out_logits.append(np.asarray(logits)[0, -1].copy())
        return _seed_token(logits, spec_for([req.params]),
                           do_sample=not req.params.is_greedy)

    def _emit_seed(self, req: ServeRequest, logits, first) -> None:
        """Record the prefill-seeded first token. A request resuming from
        preemption already holds its history — the seed (bitwise identical by
        the fold-index discipline) is re-derived, not re-recorded."""
        if req.params.has_penalties:
            # the seed draw itself saw zero counts (both backends agree); the
            # seed token is counted from the next draw on — replay included,
            # since the re-derived seed is bitwise the recorded one
            self.tok_counts[req.slot, int(first[0, 0])] += 1
        if req.out_tokens:
            self.replayed_tokens += 1
            req.replayed_tokens += 1
            return
        req.out_tokens.append(int(first[0, 0]))
        if req.params.logprobs:
            req.out_logprobs.append(
                float(np.asarray(_chosen_lp(logits, first))[0, 0]))
            tv, ti = _topk_lp(logits, req.params.logprobs)
            req.out_topk.append(([int(x) for x in np.asarray(ti)[0]],
                                 [float(x) for x in np.asarray(tv)[0]]))

    # -- paging --------------------------------------------------------------
    def _chunkable(self, req: ServeRequest) -> bool:
        """Chunked prefill only where it is bitwise-exact vs one-shot prefill:
        attention stacks always; MoE only at dropless expert capacity (capacity
        is per-call, so a finite capacity factor can drop different tokens per
        chunking); SSM when the prompt and chunk align to ``ssm_chunk``
        (state-resume preserves the scan's op order); VLM (prefix-LM mask over
        the patch prefix) and RG-LRU hybrids (splitting the associative scan
        regroups the rounding) fall back to one-shot."""
        c = self.ecfg.prefill_chunk
        if not c or self.cfg.family == "vlm" or self.cfg.frontend_tokens:
            return False
        kinds = set(transformer.layer_kinds(self.cfg))
        if "moe" in kinds:
            # dropless iff capacity >= worst-case per-expert load: cf >= E/k
            dropless = self.cfg.capacity_factor * self.cfg.experts_per_token \
                >= self.cfg.num_experts
            if not dropless:
                return False
        if kinds <= {"attn", "moe"}:
            return True
        if kinds == {"ssm"}:
            return len(req.tokens) % c == 0 and c % self.cfg.ssm_chunk == 0
        return False

    def _sharable(self, req: ServeRequest) -> bool:
        """Prefix sharing needs both exactness conditions at once: K/V a pure
        function of the token prefix (no frontend inputs, no recurrent state
        that would be missing the shared positions) and a chunked tail prefill
        to land only the unshared suffix."""
        return self._share_ok and self._chunkable(req)

    def _match(self, req: ServeRequest):
        """Prefix-index match for ``req``, capped so the padded chunk tail
        stays inside ``max_cache``. Returns ``(full_hits, partial, shared_len)``
        — ``shared_len`` prompt positions already resident (never the last
        prompt token: it is always recomputed to seed decoding)."""
        if not self._sharable(req):
            return [], None, 0
        full, partial = self.alloc.match_prefix(req.tokens)
        plen = len(req.tokens)
        c, ps = self.ecfg.prefill_chunk, self.ecfg.page_size

        def padded_end(sl):
            return sl + -(-(plen - sl) // c) * c

        sl = len(full) * ps + (partial[1] if partial else 0)
        while sl and padded_end(sl) > self.ecfg.max_cache:
            if partial is not None:       # degrade: drop the partial page,
                partial = None            # then whole full pages, until the
            else:                         # padded tail fits the block table
                full = full[:-1]
            sl = len(full) * ps
        return full, partial, sl

    def _need_pages(self, req: ServeRequest, shared_len: int = 0) -> int:
        """Worst-case pages this request can ever hold: prompt (+ chunk
        padding of the unshared tail) plus its full decode budget."""
        plen = len(req.tokens) + self.cfg.frontend_tokens
        cover = plen + req.max_new_tokens
        if self._chunkable(req):
            c = self.ecfg.prefill_chunk
            cover = max(cover, shared_len + -(-(plen - shared_len) // c) * c)
        return pages_for(cover, self.ecfg.page_size)

    def _table_row(self, slot: int) -> Array:
        return jnp.asarray(self.alloc.table()[slot])

    # -- admission -----------------------------------------------------------
    def _admit_into(self, req: ServeRequest, slot: int) -> bool:
        """Try to admit ``req`` into ``slot``; False = not enough pages *after*
        prefix-share credit (the caller defers the request). A full-page
        prefix hit — live or pinned — is adopted (refcount++), never charged.

        Under ``admission_mode="reserve"`` the request's worst case (prompt +
        full decode budget) reserves up front; under ``"preempt"`` only its
        *current* footprint (the padded prompt tail) is charged — decode
        growth acquires pages on demand and preempts a lower-priority slot if
        the pool runs dry. A preempted request re-enters here unchanged: it
        re-prefills its original prompt and re-derives its recorded tokens by
        replaying decode (same lane key, same fold indices — bitwise the same
        tokens), because prefill-computed and decode-computed logits are not
        interchangeable bitwise."""
        full, partial, sl = self._match(req)
        plen = len(req.tokens) + self.cfg.frontend_tokens
        c, ps = self.ecfg.prefill_chunk, self.ecfg.page_size
        chunkable = self._chunkable(req)
        # no-write last page: the prompt ends exactly on the shared page's
        # boundary and only its final token is unshared — the single write the
        # tail chunk makes into the shared page (position plen-1) is bitwise
        # what the page already holds (same token prefix, same position), so
        # the page is adopted as-is and the CoW fork is skipped entirely
        nowrite = (partial is not None and chunkable
                   and sl == plen - 1 and plen % ps == 0)
        if self.ecfg.admission_mode == "reserve":
            base = self._need_pages(req, sl)
        else:
            cover = sl + -(-(plen - sl) // c) * c if chunkable else plen
            # a resumed request's footprint is *proven*, not worst-case: replay
            # re-derives every recorded token before any new work, so admit it
            # only once pages for prompt + recorded tokens are actually there —
            # re-entering on the prompt cover alone stalls mid-replay, gets
            # re-evicted, and churns the pool (re-prefilling the prompt each
            # lap) without the tail ever progressing
            cover = max(cover, plen + len(req.out_tokens))
            base = pages_for(cover, ps)
        charge = base - len(full) - (1 if nowrite else 0)
        # adoption of a pinned chain consumes reclaimable capacity the charge
        # would otherwise count on — net the matched pinned pages out first
        matched = full + ([partial[0]] if partial else [])
        avail = self.alloc.available() - self.alloc.pinned_among(matched)
        if charge > min(avail, self.maxp):
            return False
        if full:
            self.alloc.adopt(slot, full, rclass=req.rclass)
        if self.ecfg.admission_mode == "reserve":
            self.alloc.reserve(slot, charge)
        if self._sharable(req):
            self.sharable_prompt_pages += pages_for(plen, ps)
            self.shared_pages_adopted += len(full) + (1 if partial else 0)
            self.prefill_positions_skipped += sl
        req.slot = slot
        if req.admit_tick < 0:
            req.admit_tick = self.tick
        if req.preempt_tick >= 0:          # resuming after preemption
            req.requeue_ticks += self.tick - req.preempt_tick
            req.preempt_tick = -1
        self.slots[slot] = req
        if self._decoding_before_admit:
            self.mid_stream_admissions += 1
        self._admitted_this_tick += 1
        if chunkable:
            if partial is not None:
                self.alloc.adopt(slot, [partial[0]], rclass=req.rclass)
                if nowrite:
                    self.nowrite_adoptions += 1
                else:
                    # the unshared tail starts mid-page: CoW-fork the donor's
                    # page (tail prefill writes divergent data into it this
                    # very admission) — the device copy replaces recomputing
                    # the shared positions
                    src, dst = self.alloc.cow_fork(slot, len(full))
                    self.pool = _copy_page(self.pool, jnp.asarray(src),
                                           jnp.asarray(dst), self.cfg)
            total = sl + -(-(plen - sl) // c) * c
            self.jobs.append(_PrefillJob(req=req, slot=slot, p0=sl, total=total,
                                         length=plen,
                                         share=self._sharable(req)))
            return True
        logits, one = _prefill_one(self.params, self.cfg, req.prompts(),
                                   self.ecfg.max_cache, self.router_bias)
        first = self._seed_slot(req, logits)
        self.alloc.ensure(slot, pages_for(plen, ps))
        self.pool, self.last, self.active = _splice(
            self.pool, one, jnp.asarray(slot), self._table_row(slot), first,
            self.last, self.active, self.cfg)
        self.active_host[slot] = True
        self.pos_host[slot] = plen
        self.emitted[slot] = 1
        req.prefill_tokens += plen
        self.prefill_tokens += plen
        self._emit_seed(req, logits, first)
        return True

    # -- preemption ----------------------------------------------------------
    def _victim_score(self, req: ServeRequest) -> tuple:
        """Preemption priority, highest evicted first: anergic classes, then
        classes already over their latency budget, then the highest remembered
        cost, then the *latest arrival* (within an immune-equal group the
        oldest resident is never evicted, so it always runs to completion and
        frees its pages — the classic livelock-free discipline; scoring by
        progress or preemption count instead lets pressure either starve one
        victim or rotate across the whole pool, both of which blow up the
        tail); least progress / rid break remaining ties (FIFO engines score
        on arrival/progress alone), so victim choice is always
        deterministic."""
        over = 1.0 if self._over_budget_now(req) else 0.0
        if self.admission is not None:
            anergy = float(self.admission.anergy.level[req.rclass])
            cost = self.admission.remembered_cost(req.rclass)
        else:
            anergy = cost = 0.0
        # group-aware: evicting one member cascades to its resident siblings
        # (_preempt), so a member's progress stake is the whole group's —
        # scoring a lane alone would let page pressure evict an n-lane group
        # to reclaim one lane's pages while destroying n lanes of work
        progress = len(req.out_tokens)
        if req.group >= 0:
            progress = sum(len(r.out_tokens) for r in self.slots
                           if r is not None and r.group == req.group)
        return (anergy, over, cost, req.arrival, -progress, req.rid)

    def _pick_victim(self) -> Optional[int]:
        """The occupied slot preemption should evict first (the stalling slot
        itself is a candidate — if it is the lowest-priority resident, it
        self-preempts rather than evicting more deserving work)."""
        best, best_score = None, None
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            score = self._victim_score(req)
            if best_score is None or score > best_score:
                best, best_score = slot, score
        return best

    def _free_slot(self, slot: int) -> None:
        """Return ``slot`` to the pool: drop its request binding, release its
        pages (refcount--; shared and pinnable chains stay resident), zero its
        host-side decode state, and reset its sampling lane to the free-slot
        argmax row."""
        self.slots[slot] = None
        self.pool, self.active = _release(self.pool, self.active,
                                          jnp.asarray(slot), self.cfg)
        self.alloc.release(slot)
        self.active_host[slot] = False
        self.pos_host[slot] = 0
        self.emitted[slot] = 0
        self.samp_temp[slot] = 0.0
        self.samp_topk[slot] = 0
        self.samp_topp[slot] = 1.0
        self.samp_rep[slot] = 1.0
        self.samp_pres[slot] = 0.0
        self.samp_freq[slot] = 0.0
        self.tok_counts[slot] = 0
        self._spec_cache = None

    def _preempt_one(self, slot: int) -> None:
        """Evict ``slot``'s request: drop its pages and any in-flight prefill
        job, and re-queue it at the front for exact re-entry — re-admission
        re-prefills the original prompt and replays its recorded tokens
        through decode, reproducing them bitwise."""
        req = self.slots[slot]
        self.jobs = deque(j for j in self.jobs if j.slot != slot)
        self._free_slot(slot)
        req.slot = -1
        req.preemptions += 1
        req.preempt_tick = self.tick
        self.preemptions += 1
        self.preempted_rids.add(req.rid)
        if req.group >= 0:
            # eviction may drop the shared chain's last refcount — the ready
            # bit is stale until some lane's re-prefill re-registers it.
            # Leaving it set lets every lane re-admit at once, each paying a
            # full un-shared prefill: the group's footprint nearly doubles,
            # runs the pool dry again, and the cascade livelocks.
            self._group_ready.discard(req.group)
        self.queue.appendleft(req)

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s request — and, for a slot-group member, its
        resident sibling lanes with it (joint preemption: the group moves
        through the queue as a unit and its shared prefix refcounts drop
        together). Eviction order is descending lane, so the appendleft
        sequence leaves lane 0 at the queue front and the group re-admits in
        lane order — lane 0 re-registers the shared prefix before its
        siblings re-adopt it."""
        req = self.slots[slot]
        targets = [slot]
        if req.group >= 0:
            targets = [s for _, s in sorted(
                ((r.lane, s) for s, r in enumerate(self.slots)
                 if r is not None and r.group == req.group), reverse=True)]
        for s in targets:
            self._preempt_one(s)

    def _acquire(self, slot: int, npages: int) -> bool:
        """Grow ``slot`` to ``npages``, resolving page exhaustion by
        preemption (admission_mode="preempt"). Returns False when the slot's
        own request was the lowest-priority resident and preempted itself —
        the caller must stop driving that slot. Each eviction releases a
        resident's refcounts, so the loop strictly shrinks occupancy and
        terminates; a lone request always fits (submit() rejects anything
        whose worst case exceeds the pool)."""
        if self.ecfg.admission_mode == "reserve":
            self.alloc.ensure(slot, npages)       # covered by the reservation
            return True
        req = self.slots[slot]
        while True:
            # a victim's group cascade may have evicted this slot's own
            # request as a sibling — growing the (now free) slot would charge
            # pages to nobody; the caller must stop driving it
            if req is not None and self.slots[slot] is not req:
                return False
            try:
                self.alloc.ensure(slot, npages)
                return True
            except OutOfPages:
                victim = self._pick_victim()
                if victim is None or victim == slot:
                    self._preempt(slot)
                    return False
                self._preempt(victim)

    def _admit(self):
        self._admitted_this_tick = 0
        # mid-stream means spliced in while another slot was actually decoding
        # — slots filled earlier in this same admission pass don't count
        self._decoding_before_admit = any(r is not None for r in self.slots)
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.queue:
            return
        if self.admission is None:                      # FIFO baseline
            while free and self.queue:
                if self._deferred_member(self.queue[0]) \
                        or not self._admit_into(self.queue[0], free[0]):
                    break     # strict FIFO: an unfit head blocks the line
                self.queue.popleft()
                free.pop(0)
            return
        adm = self.admission
        # tolerance turned shedding: requests of anergic classes are rejected
        # outright (not parked — a parked convoy would hold queue pressure high
        # and block the IL-2 revival it is waiting for). Shedding one group
        # member sheds the group: a half-shed group can never finish jointly.
        for req in [r for r in self.queue if not adm.admissible(r.rclass)]:
            if req not in self.queue:
                continue                  # already cancelled with its group
            self.queue.remove(req)
            req.finish_reason = "shed"
            self.shed.append(req)
            req.finish_tick = self.tick
            req.finish_time = time.perf_counter()
            if req.group >= 0:
                self._cancel_group(req.group, "shed")
        if adm.throttled():                             # delayed suppression
            return
        # anticipation: order by *remembered* class cost, not queue position;
        # a candidate the page pool cannot hold yet is skipped (deferred), so
        # a big request waiting for pages never blocks smaller ones — the
        # paged pool's admissive win over fixed rows
        cost = self._predicted_costs()
        candidates = sorted(self.queue,
                            key=lambda r: (cost[r.rclass], r.arrival, r.rid))
        for req in candidates:
            if not free:
                break
            if self._deferred_member(req):
                continue
            if not self._admit_into(req, free[0]):
                continue
            self.queue.remove(req)
            free.pop(0)

    def _deferred_member(self, req: ServeRequest) -> bool:
        """Sibling lanes of a sharable group wait until some member's prompt
        pages are registered in the prefix index (lane 0's prefill landing),
        so they adopt the shared pages (refcount++) instead of each paying a
        full prefill — the group's prompt is charged once. Non-sharable
        configs never defer: there is nothing to adopt.

        The wait is bounded by a *lower lane still pending*: if no sibling
        ahead of this lane is queued or mid-prefill, nobody is ever going to
        register the chain (lane 0 already retired, or its registration was
        evicted with it), so the lane admits now — adopting the chain if it
        survived, paying its own prefill if not. Deferring on the ready bit
        alone would park such a lane forever."""
        if req.group < 0 or req.lane == 0 or not self._sharable(req):
            return False
        if req.group in self._group_ready:
            return False
        return (any(q.group == req.group and q.lane < req.lane
                    for q in self.queue)
                or any(j.req.group == req.group and j.req.lane < req.lane
                       for j in self.jobs))

    def _cancel_group(self, gid: int, reason: str) -> None:
        """Joint retirement on abnormal exit: one member shed or corrupted
        takes its sibling lanes with it — resident lanes release their slots,
        queued lanes leave the queue, all with the member's ``reason`` (the
        stream reports each, and the group book folds them into one abnormal
        parent output). A group either completes whole or fails whole."""
        sink = {"shed": self.shed, "corrupted": self.corrupted,
                "rejected": self.rejected}[reason]
        for slot, r in enumerate(self.slots):
            if r is None or r.group != gid:
                continue
            self.jobs = deque(j for j in self.jobs if j.slot != slot)
            self._free_slot(slot)
            r.slot = -1
            r.finish_reason = reason
            r.finish_tick = self.tick
            r.finish_time = time.perf_counter()
            sink.append(r)
        for r in [q for q in self.queue if q.group == gid]:
            self.queue.remove(r)
            r.finish_reason = reason
            r.finish_tick = self.tick
            r.finish_time = time.perf_counter()
            sink.append(r)
        self._group_ready.discard(gid)

    def _predicted_costs(self) -> np.ndarray:
        """Per-class cost estimate: the EMA memory, floored by what currently
        running requests have already revealed (ticks held so far is a lower
        bound on their class's true cost). Without the reveal, the cold-start
        memory is all zeros and the first burst of heavies convoys the pool."""
        cost = np.asarray(self.admission.memory.value, np.float64).copy()
        for r in self.slots:
            if r is not None:
                cost[r.rclass] = max(cost[r.rclass], self.tick - r.admit_tick)
        return cost

    # -- chunked prefill ------------------------------------------------------
    def _finish_job(self, job: _PrefillJob, logits):
        """Final chunk landed: sample/argmax the first token from its logits,
        activate the slot, and (for sharable prompts) register its full prompt
        pages in the prefix index, so later admissions can adopt them — the
        pages' K/V is now fully resident."""
        first = self._seed_slot(job.req, logits)
        self.pool, self.last, self.active = _activate(
            self.pool, self.last, self.active, jnp.asarray(job.slot),
            first, jnp.asarray(job.length, jnp.int32))
        self.active_host[job.slot] = True
        self.pos_host[job.slot] = job.length
        self.emitted[job.slot] = 1
        self._emit_seed(job.req, logits, first)
        if job.share:
            self.alloc.register_prefix(job.slot, job.req.tokens,
                                       rclass=job.req.rclass)
            if job.req.group >= 0:
                # the group's shared prompt pages are now adoptable: sibling
                # lanes deferred in _admit may enter and refcount++ them
                self._group_ready.add(job.req.group)

    def _prefill_tick(self):
        """Land one chunk of up to ``prefill_streams`` front prefill jobs (one
        batched compiled call on attention stacks; one job per tick
        otherwise). The jobs' slots stay inactive while the other slots
        decode, so long prompts never stall the pool — and with multiple
        streams they no longer serialize behind each other either."""
        if not self.jobs:
            return
        c, page = self.ecfg.prefill_chunk, self.ecfg.page_size
        if self._multi_prefill:
            j = self.ecfg.prefill_streams
            take: list[_PrefillJob] = []
            while self.jobs and len(take) < j:
                job = self.jobs.popleft()
                if not self._acquire(job.slot, pages_for(job.p0 + c, page)):
                    continue          # the job's own request self-preempted
                # that acquire may have preempted a job already taken: keep
                # only lanes whose slot still belongs to their request
                take = [t for t in take if self.slots[t.slot] is t.req]
                take.append(job)
            if not take:
                return
            toks = np.zeros((j, c), np.int32)
            tables = np.zeros((j, self.maxp), np.int32)   # padding lanes: null
            p0s = np.zeros((j,), np.int32)
            last_idxs = np.zeros((j,), np.int32)
            for lane, job in enumerate(take):
                end = job.p0 + c
                seg = job.req.tokens[job.p0:min(end, len(job.req.tokens))]
                toks[lane, :len(seg)] = seg
                p0s[lane] = job.p0
                last_idxs[lane] = min(max(job.length - 1 - job.p0, 0), c - 1)
                job.req.prefill_tokens += len(seg)
                self.prefill_tokens += len(seg)
            tbl = self.alloc.table()          # one snapshot after the acquires
            for lane, job in enumerate(take):
                tables[lane] = tbl[job.slot]
            logits_j, self.pool = _prefill_chunks(
                self.params, self.cfg, {"tokens": jnp.asarray(toks)},
                self.pool, jnp.asarray(tables), jnp.asarray(p0s),
                jnp.asarray(last_idxs), self.router_bias)
            self.chunked_prefill_chunks += len(take)
            self.prefill_batch_calls += 1
            unfinished = []
            for lane, job in enumerate(take):
                job.p0 += c
                if job.p0 >= job.total:
                    self._finish_job(job, logits_j[lane:lane + 1])
                else:
                    unfinished.append(job)
            for job in reversed(unfinished):      # keep front-of-queue order
                self.jobs.appendleft(job)
            return
        job = self.jobs[0]
        end = job.p0 + c
        if not self._acquire(job.slot, pages_for(end, page)):
            return                    # the job's request was requeued
        toks = np.zeros((c,), np.int32)
        seg = job.req.tokens[job.p0:min(end, len(job.req.tokens))]
        toks[:len(seg)] = seg
        job.req.prefill_tokens += len(seg)
        self.prefill_tokens += len(seg)
        chunk = {"tokens": jnp.asarray(toks)[None]}
        if self.cfg.family == "audio":
            fr = np.zeros((c, self.cfg.frontend_dim), np.float32)
            fseg = job.req.frames[job.p0:min(end, len(job.req.frames))]
            fr[:len(fseg)] = fseg
            chunk["frames"] = jnp.asarray(fr)[None]
        last_idx = min(max(job.length - 1 - job.p0, 0), c - 1)
        logits, self.pool = _prefill_chunk(
            self.params, self.cfg, chunk, self.pool, self._table_row(job.slot),
            jnp.asarray(job.p0, jnp.int32), jnp.asarray(last_idx, jnp.int32),
            jnp.asarray(job.slot, jnp.int32), self.router_bias)
        self.chunked_prefill_chunks += 1
        job.p0 = end
        if end >= job.total:
            self.jobs.popleft()
            self._finish_job(job, logits)

    # -- retirement ----------------------------------------------------------
    def _slo(self, req: ServeRequest) -> tuple:
        """``(latency, bar)`` for this completed request's SLO accounting,
        both in ONE unit: a declared ``deadline`` is wall-clock seconds and is
        judged against wall-clock latency; otherwise tick latency is judged
        against the tick-denominated ``EngineConfig.latency_budget``. (The old
        ``_budget`` helper handed a wall-clock deadline to tick comparisons —
        a deadline-bearing request was judged over/under budget in the wrong
        unit.)"""
        if req.deadline is not None and req.wall_latency_s is not None:
            return req.wall_latency_s, float(req.deadline)
        return float(req.latency), float(self.ecfg.latency_budget)

    def _met_budget(self, req: ServeRequest) -> bool:
        """Did this completed request meet its latency bar (its own wall-clock
        deadline if declared, the engine-wide tick budget otherwise)?"""
        lat, bar = self._slo(req)
        return lat <= bar

    def _over_budget_now(self, req: ServeRequest) -> bool:
        """Mid-flight over-budget signal (victim scoring), same unit
        discipline as ``_slo`` but on elapsed time: wall-clock elapsed against
        a declared deadline, tick elapsed against the engine budget."""
        if req.deadline is not None:
            return req.submit_time >= 0 and \
                time.perf_counter() - req.submit_time > req.deadline
        return (self.tick - req.arrival) > self.ecfg.latency_budget

    def _finished(self, req: ServeRequest) -> bool:
        """Per-request retirement: any of the request's stop-token ids ends it
        the tick the token is emitted (the token is kept, like the old
        ``eos_id``); otherwise its own ``max_new_tokens`` budget does. Records
        the ``finish_reason`` the RequestOutput stream reports."""
        p = req.params
        if p.stop and req.out_tokens and req.out_tokens[-1] in p.stop:
            req.finish_reason = "stop"
            return True
        if len(req.out_tokens) >= p.max_new_tokens:
            req.finish_reason = "length"
            return True
        return False

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None or not self.active_host[slot] \
                    or not self._finished(req):
                continue
            req.finish_tick = self.tick
            req.finish_time = time.perf_counter()
            self.completed.append(req)
            self._free_slot(slot)             # incl. unused reservation (stop)
            if self.admission is not None:
                # cost = slot-ticks actually consumed: emitted tokens PLUS any
                # recorded tokens re-derived after preemption — a replayed
                # token burns the same decode tick a fresh one does, and
                # charging emissions alone taught the memory that exactly the
                # preempt-prone classes it should suppress were cheap
                lat, bar = self._slo(req)
                self.admission.observe_completion(
                    req.rclass,
                    cost=float(len(req.out_tokens) + req.replayed_tokens),
                    latency=lat, budget=bar)

    def _retire_corrupted(self, slot: int) -> None:
        """Retire a lane whose decode logits came back non-finite: the
        request terminates with ``finish_reason="corrupted"`` (surfaced via
        the stream, counted against goodput) and the slot's pages return to
        the pool — streaming the garbage token, or letting the poisoned KV
        keep feeding the shared sampling step, would be worse than losing
        the lane. No cost observation: the corruption is a hardware/kernel
        event, not a workload signal the immune memory should learn."""
        req = self.slots[slot]
        req.finish_reason = "corrupted"
        req.finish_tick = self.tick
        req.finish_time = time.perf_counter()
        self.corrupted.append(req)
        self._free_slot(slot)
        if req.group >= 0:
            # joint retirement: the group cannot finish whole anymore
            self._cancel_group(req.group, "corrupted")

    # -- decode ticks --------------------------------------------------------
    def _plain_step(self, do_sample: bool, use_penalties: bool,
                    want_lp: bool, want_k: int) -> None:
        """One sequential decode tick: one token for every active slot."""
        # each lane's fold_in index is its request's emitted-token count
        # since admission (seed included) — identical to the one-shot
        # loop's index, and during post-preemption replay it re-walks
        # 0..n-1 so the re-derived tokens are bitwise the recorded ones
        counts = jnp.asarray(self.emitted, jnp.int32)
        spec = self._pool_spec() if do_sample else self._null_spec
        pen = jnp.asarray(self.tok_counts) if use_penalties else None
        nxt, self.last, self.pool, ok, logits, lps, topk = _decode_tick(
            self.params, self.cfg_decode, self.pool, self.last, self.active,
            jnp.asarray(self.alloc.table()), self.router_bias, self.frames,
            spec, counts, pen, attn_backend=self.ecfg.attn_backend,
            do_sample=do_sample,
            return_logits=self.ecfg.capture_logits,
            return_logprobs=want_lp, use_penalties=use_penalties,
            return_topk=want_k)
        nxt_host = np.asarray(nxt[:, 0])
        ok_host = np.asarray(ok)
        lg_host = np.asarray(logits[:, -1]) if logits is not None else None
        lp_host = np.asarray(lps[:, 0]) if lps is not None else None
        tv_host, ti_host = (np.asarray(topk[0]), np.asarray(topk[1])) \
            if topk is not None else (None, None)
        bad: list[int] = []
        for slot, req in enumerate(self.slots):
            if req is None or not self.active_host[slot] \
                    or self._finished(req):
                continue
            if not ok_host[slot]:
                bad.append(slot)    # poisoned lane: token is garbage
                continue
            if self.emitted[slot] >= len(req.out_tokens):
                req.out_tokens.append(int(nxt_host[slot]))
                if lg_host is not None:
                    req.out_logits.append(lg_host[slot].copy())
                if lp_host is not None and req.params.logprobs:
                    req.out_logprobs.append(float(lp_host[slot]))
                if tv_host is not None and req.params.logprobs:
                    k = req.params.logprobs
                    req.out_topk.append(
                        ([int(x) for x in ti_host[slot][:k]],
                         [float(x) for x in tv_host[slot][:k]]))
            else:
                self.replayed_tokens += 1   # replaying recorded history
                req.replayed_tokens += 1
            if req.params.has_penalties:
                # the emitted (or bitwise re-derived) token joins the lane's
                # penalty counts for every draw after this one
                self.tok_counts[slot, int(nxt_host[slot])] += 1
            self.emitted[slot] += 1
        self.pos_host[self.active_host] += 1
        for slot in bad:
            self._retire_corrupted(slot)

    def _spec_step(self) -> None:
        """One self-speculative tick: fused draft+verify, then the host-side
        greedy accept loop. Per lane: accept the longest draft prefix where
        ``d_j == argmax(row j-1)`` plus the bonus token ``argmax(row a)``,
        stopping early at the request's stop/budget boundary — every emitted
        token is bitwise the sequential greedy tick's, so preemption replay
        and the parity oracle hold across spec ticks unchanged."""
        k = self.ecfg.spec_decode
        drafts, am, ok, logits, new_pool = specdec.spec_tick(
            self.params, self.cfg_decode, self.pool, self.last, self.active,
            jnp.asarray(self.alloc.table()), k=k,
            depth=self.ecfg.spec_draft_layers,
            attn_backend=self.ecfg.attn_backend,
            return_logits=self.ecfg.capture_logits,
            router_bias=self.router_bias)
        drafts_h = np.asarray(drafts)
        am_h = np.asarray(am)
        ok_h = np.asarray(ok)
        lg_h = np.asarray(logits) if logits is not None else None
        last_h = np.array(self.last)          # writable copy
        self.spec_ticks += 1
        bad: list[int] = []
        for slot, req in enumerate(self.slots):
            if req is None or not self.active_host[slot] \
                    or self._finished(req):
                continue
            if not ok_h[slot]:
                bad.append(slot)
                continue
            a = 0
            while a < k and int(drafts_h[slot, a]) == int(am_h[slot, a]):
                a += 1
            self.spec_drafted += k
            emitted_now = 0
            for j in range(a + 1):
                tok = int(drafts_h[slot, j]) if j < a else int(am_h[slot, a])
                if self.emitted[slot] >= len(req.out_tokens):
                    req.out_tokens.append(tok)
                    if lg_h is not None:
                        req.out_logits.append(lg_h[slot, j].copy())
                else:
                    self.replayed_tokens += 1
                    req.replayed_tokens += 1
                self.emitted[slot] += 1
                emitted_now += 1
                last_h[slot, 0] = tok
                if self._finished(req):
                    break               # stop/budget: the rest is never real
            self.spec_accepted += min(emitted_now, a)
            self.spec_emitted += emitted_now
            # pos advances by exactly what was emitted: verify wrote K/V for
            # positions pos..pos+k, of which pos..pos+emitted_now-1 hold
            # precisely what sequential decode would have written; the stale
            # tail is causally masked and overwritten before it is ever read
            self.pos_host[slot] += emitted_now
        self.last = jnp.asarray(last_h)
        self.pool = {"layers": new_pool["layers"],
                     "pos": jnp.asarray(self.pos_host, jnp.int32)}
        for slot in bad:
            self._retire_corrupted(slot)

    # -- one tick ------------------------------------------------------------
    def step(self):
        """One engine tick: admit into free slots, land a prefill chunk, decode
        one token for every active slot, retire finished sequences, advance the
        immune states."""
        self._admit()
        self._prefill_tick()
        self.concurrency_hw = max(self.concurrency_hw,
                                  sum(r is not None for r in self.slots))
        # sample only when a resident request asks to: both do_sample variants
        # of the compiled step stay in jit's cache, so all-greedy stretches
        # run the pure argmax step even after sampled traffic. Penalties ride
        # the sampling lane (greedy-with-penalties is its temperature-0 row).
        use_penalties = any(r is not None and r.params.has_penalties
                            for r in self.slots)
        do_sample = use_penalties or any(
            r is not None and not r.params.is_greedy for r in self.slots)
        want_lp = any(r is not None and r.params.logprobs
                      for r in self.slots)
        want_k = max((r.params.logprobs for r in self.slots
                      if r is not None), default=0)
        # self-speculative tick: only when every resident is greedy with no
        # penalty/logprob state to advance per emitted token — then one fused
        # draft+verify step can emit up to spec_decode+1 tokens per lane,
        # each bitwise what the sequential greedy tick would have emitted
        use_spec = self._spec_ok and not do_sample and not want_lp
        page = self.ecfg.page_size
        lookahead = self.ecfg.spec_decode if use_spec else 0
        for slot in np.flatnonzero(self.active_host):
            slot = int(slot)
            if not self.active_host[slot]:
                continue              # preempted by an earlier slot's growth
            # decode writes at pos (a spec tick at pos..pos+k, clamped to the
            # slot's logical capacity — writes past it route to the null page
            # and belong to tokens the budget check never emits): append pages
            # lazily at the boundary, preempting the lowest-priority resident
            # if the pool is dry
            cover = min(int(self.pos_host[slot]) + 1 + lookahead,
                        self.ecfg.max_cache)
            self._acquire(slot, pages_for(cover, page))
        if self.active_host.any():
            if use_spec:
                self._spec_step()
            else:
                self._plain_step(do_sample, use_penalties, want_lp, want_k)
        self._retire()
        if self.admission is not None:
            demand = np.zeros(self.ecfg.num_classes, np.float64)
            for r in self.queue:
                demand[r.rclass] += 1.0
            self.admission.end_tick(self._admitted_this_tick, len(self.queue),
                                    demand, self._predicted_costs())
        self.tick += 1

    # -- driver --------------------------------------------------------------
    def _output_for(self, req: ServeRequest, tick: int, new_tokens: list,
                    finished: bool,
                    reason: Optional[str] = None) -> RequestOutput:
        done = finished and reason is None
        new_lp = full_lp = topk = None
        if req.params.logprobs:
            n = len(req.out_tokens)
            new_lp = list(req.out_logprobs[n - len(new_tokens):n])
            full_lp = list(req.out_logprobs)
            topk = list(req.out_topk)
        return RequestOutput(
            rid=req.rid, new_tokens=new_tokens, tokens=list(req.out_tokens),
            finished=finished,
            finish_reason=reason if reason is not None
            else (req.finish_reason if done else None),
            tick=tick, arrival=req.arrival, admit_tick=req.admit_tick,
            finish_tick=req.finish_tick,
            latency_ticks=req.latency if done else None,
            wall_latency_s=req.wall_latency_s if done else None,
            deadline_met=self._met_budget(req) if done else None,
            new_logprobs=new_lp, logprobs=full_lp, top_logprobs=topk,
            preemptions=req.preemptions, requeue_ticks=req.requeue_ticks)

    def stream(self, requests: Optional[list] = None,
               max_ticks: int = 10_000) -> Iterator[RequestOutput]:
        """Open-loop drive as an iterator: submit each request at its
        ``arrival`` tick, step until everything completes (or ``max_ticks``),
        and yield a ``RequestOutput`` per request per tick of progress —
        ``new_tokens`` is the delta since the previous output for that rid,
        and the terminal output carries the finish reason and the
        tick/wall-clock latency accounting. Requests the engine refuses are
        reported too (finish_reason "rejected" / "shed", including refusals
        from ``submit()`` calls made before the stream started), and requests
        still queued or in-flight when the ``max_ticks`` backstop fires get a
        final ``finish_reason="timeout"`` output (``finished=False`` — the
        engine still holds them and can be stepped further), so the stream is
        a complete account of every submission's fate."""
        pending = sorted(requests or [], key=lambda r: (r.arrival, r.rid))
        i = 0
        sent: dict = {}                      # rid -> tokens already yielded
        while True:
            while i < len(pending) and pending[i].arrival <= self.tick:
                self.submit(pending[i])
                i += 1
            # kept current every iteration (not just on drain): a consumer
            # may break out of the stream early, and arrivals never let in
            # must still count as demand in stats() — otherwise a policy that
            # stalls into the backstop flatters its goodput
            self.unsubmitted = len(pending) - i
            t = self.tick
            for req in self.rejected[self._reported_rejected:]:
                out = self._output_for(req, t, [], True, reason="rejected")
                yield out
                done = self.group_book.offer(req, out)
                if done is not None:
                    yield done
            self._reported_rejected = len(self.rejected)
            drained = (i == len(pending) and not self.queue
                       and all(r is None for r in self.slots))
            if drained or t >= max_ticks:
                if not drained:              # backstop: account for the rest
                    live = [r for r in self.slots if r is not None]
                    for req in live + list(self.queue):
                        k = sent.get(req.rid, 0)
                        yield self._output_for(
                            req, t, list(req.out_tokens[k:]), False,
                            reason="timeout")
                break
            ndone = len(self.completed)
            self.step()
            for req in self.shed[self._reported_shed:]:  # anergy refusals
                out = self._output_for(req, t, [], True, reason="shed")
                yield out
                done = self.group_book.offer(req, out)
                if done is not None:
                    yield done
            self._reported_shed = len(self.shed)
            for req in self.corrupted[self._reported_corrupted:]:
                out = self._output_for(req, t, [], True, reason="corrupted")
                yield out
                done = self.group_book.offer(req, out)
                if done is not None:
                    yield done
            self._reported_corrupted = len(self.corrupted)
            live = [r for r in self.slots if r is not None]
            for req in live + self.completed[ndone:]:
                n = len(req.out_tokens)
                k = sent.get(req.rid, 0)
                finished = req.finish_tick == t
                if n == k and not finished:
                    continue
                sent[req.rid] = n
                out = self._output_for(req, t, list(req.out_tokens[k:n]),
                                       finished)
                yield out
                if finished:
                    # group member landed: when it is the group's last lane,
                    # the assembled parent output follows it in the stream
                    done = self.group_book.offer(req, out)
                    if done is not None:
                        yield done

    def run(self, requests: list, max_ticks: int = 10_000) -> dict:
        """Open-loop drive: submit each request at its ``arrival`` tick, run
        until everything completes (or ``max_ticks``); returns ``stats()``.
        ``stream()`` with the outputs discarded."""
        for _ in self.stream(requests, max_ticks=max_ticks):
            pass
        return self.stats()

    def stats(self) -> dict:
        lat = np.asarray([r.latency for r in self.completed], np.float64)
        wall = np.asarray([r.wall_latency_s for r in self.completed
                           if r.wall_latency_s is not None], np.float64) * 1e3
        toks = int(sum(len(r.out_tokens) for r in self.completed))
        # goodput bar is per-request: a request's own wall-clock deadline when
        # declared, the engine-wide tick budget otherwise (unit-consistent)
        in_budget = sum(1 for r in self.completed if self._met_budget(r))
        in_flight = sum(r is not None for r in self.slots)
        # every request the trace produced, wherever it ended up — the goodput
        # denominator, so a policy that stalls into the max_ticks backstop
        # (requests still queued, in-flight, or never submitted) cannot
        # flatter itself by under-counting demand
        demand = (len(self.completed) + len(self.shed) + len(self.rejected)
                  + len(self.corrupted) + len(self.queue) + in_flight
                  + self.unsubmitted)
        # no completions -> the tail is unbounded, not "best ever"
        empty = float("inf")
        return {
            "policy": self.ecfg.policy,
            "ticks": self.tick,
            "completed": len(self.completed),
            "shed": len(self.shed),
            "rejected": len(self.rejected),
            "corrupted": len(self.corrupted),
            "unserved": len(self.queue) + in_flight + self.unsubmitted,
            "tokens": toks,
            "throughput": toks / max(self.tick, 1),
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else empty,
            "p99_latency": float(np.percentile(lat, 99)) if lat.size else empty,
            "max_latency": float(lat.max()) if lat.size else empty,
            # fraction of total demand served within the latency budget: shed
            # and rejected requests count against goodput — rejection is not a
            # free lunch
            "goodput": in_budget / max(demand, 1),
            "mid_stream_admissions": self.mid_stream_admissions,
            # paged-memory telemetry: the perf trajectory BENCH_serve.json tracks
            "page_size": self.ecfg.page_size,
            "pages_budget": self.alloc.usable_pages,
            "pages_in_use": self.alloc.pages_in_use,
            "pages_hw": self.alloc.high_water,
            "concurrency_hw": self.concurrency_hw,
            "chunked_prefill_chunks": self.chunked_prefill_chunks,
            "prefill_batch_calls": self.prefill_batch_calls,
            # prefix-sharing telemetry: adopted = refcount++ instead of
            # reserve+prefill; hit rate over the prompt pages of sharable
            # admissions; skipped = prompt positions never re-forwarded
            "attn_backend": self.ecfg.attn_backend,
            "prefix_sharing": bool(self.ecfg.prefix_sharing),
            "shared_pages_adopted": self.shared_pages_adopted,
            "cow_forks": self.alloc.cow_forks,
            "prefill_positions_skipped": self.prefill_positions_skipped,
            "prefix_hit_rate": self.shared_pages_adopted
            / max(self.sharable_prompt_pages, 1),
            "prefill_tokens": self.prefill_tokens,
            "nowrite_adoptions": self.nowrite_adoptions,
            # KV memory hierarchy: pinned prefix cache + preemption telemetry
            "admission_mode": self.ecfg.admission_mode,
            "pin_pages": self.alloc.pin_pages,
            "pages_pinned": self.alloc.pages_pinned,
            "pins": self.alloc.pins,
            "pinned_pages_adopted": self.alloc.pinned_hits,
            "pin_evictions": self.alloc.evictions,
            "pinned_hit_rate": self.alloc.pinned_hits
            / max(self.sharable_prompt_pages, 1),
            "preemptions": self.preemptions,
            "preempted_requests": len(self.preempted_rids),
            "replayed_tokens": self.replayed_tokens,
            # request-facing API telemetry: wall-clock latency over
            # completions (ms) and how much of the traffic asked to sample
            "p50_wall_ms": float(np.percentile(wall, 50)) if wall.size
            else empty,
            "p99_wall_ms": float(np.percentile(wall, 99)) if wall.size
            else empty,
            "sampled_requests": sum(1 for r in self.completed
                                    if not r.params.is_greedy),
            "deadline_requests": sum(1 for r in self.completed
                                     if r.deadline is not None),
            # self-speculative decoding: accept rate over proposed drafts and
            # how much of the emitted stream came out of fused spec ticks
            "spec_decode": self.ecfg.spec_decode,
            "spec_ticks": self.spec_ticks,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_emitted": self.spec_emitted,
            "spec_accept_rate": self.spec_accepted / max(self.spec_drafted, 1),
            # slot groups
            "groups_submitted": self.groups_submitted,
            "group_members_completed": sum(1 for r in self.completed
                                           if r.group >= 0),
            "penalized_requests": sum(1 for r in self.completed
                                      if r.params.has_penalties),
        }

    # -- placement telemetry (read by serve.router for global placement) -----
    def class_costs(self) -> np.ndarray:
        """Per-class remembered decode cost (the ``ImmuneMemory`` slot-tick
        EMA) — the router's load model. All zeros under the FIFO policy,
        which has no memory."""
        if self.admission is None:
            return np.zeros(self.ecfg.num_classes, np.float64)
        return np.asarray(self.admission.memory.value, np.float64)

    def anergy_levels(self) -> np.ndarray:
        """Per-class anergy levels. A router drains a replica for classes it
        holds anergic (no new placements until IL-2 revives them) — placing
        there would only have local admission shed the request."""
        if self.admission is None:
            return np.zeros(self.ecfg.num_classes, np.float64)
        return np.asarray(self.admission.anergy.level, np.float64)

    def prefix_affinity(self, req: ServeRequest) -> int:
        """Prompt positions of ``req`` already resident in this engine's page
        pool (live shared or pinned chains). Placement affinity: routing the
        request here skips exactly this much prefill."""
        return self._match(req)[2]

    def pinned_chain_keys(self) -> list:
        """Token-content keys of this engine's pinned prefix-cache pages."""
        return self.alloc.pinned_chain_keys()

    def occupancy(self) -> int:
        """Queued + resident (incl. mid-prefill) requests — the classic
        join-shortest-queue load signal, memory-free by design."""
        return len(self.queue) + sum(r is not None for r in self.slots)

    def evacuate(self) -> list:
        """Strip every in-flight and queued request for re-placement on
        another replica (crash recovery — the fleet router calls this when it
        declares this replica dead). Only host-side request objects survive:
        recorded ``out_tokens`` plus the original prompt are exactly what
        re-admission elsewhere needs for bitwise-exact recovery (re-prefill
        the proven prompt, replay the recorded tokens through decode — the
        preemption machinery, pointed at a different replica). The device
        state is abandoned; the caller must fence this engine (never step it
        again). Returns residents in slot order, then the queue in order —
        deterministic, so re-placement is reproducible."""
        lost = []
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            req.slot = -1
            lost.append(req)
        lost.extend(self.queue)
        self.queue.clear()
        self.jobs.clear()
        self.slots = [None] * self.ecfg.num_slots
        self.active_host[:] = False
        self.pos_host[:] = 0
        self.emitted[:] = 0
        for slot in range(self.ecfg.num_slots):
            self.alloc.release(slot)      # keep the (dead) books consistent
        return lost

    # -- durability: warm-state snapshot export / import ---------------------
    def export_warm_state(self) -> tuple[dict, list]:
        """Snapshot this engine's *learned* state: the indexed prefix forest
        (pinned cache entries and live prompt chains alike — immutable once
        registered; token keys + the pages' actual K/V, gathered from the
        device pool) and the immune memories (per-class cost EMAs, anergy,
        regulator, pin-value EMAs). Returns ``(meta, kv)`` — a JSON-able dict plus the
        host K/V arrays, page-major then leaf-major, ``meta["kv_per_page"]``
        arrays per page. In-flight request state is deliberately NOT here:
        the write-ahead journal owns requests; the snapshot owns what was
        *learned* from them. Reads device state but never mutates it, so a
        snapshot cadence never stalls decode."""
        forest = self.alloc.export_pinned()
        kv: list[np.ndarray] = []
        per = 0
        for e in forest:
            page = e.pop("page")
            arrs = self._gather_page_kv(page)
            per = len(arrs)
            kv.extend(arrs)
        meta = {
            "forest": forest,
            "kv_per_page": per,
            "pin_memory": self.alloc.pin_memory_state().tolist(),
            "admission": (self.admission.export_state()
                          if self.admission is not None else None),
        }
        return meta, kv

    def import_warm_state(self, meta: dict, kv: list) -> int:
        """Rebuild the warm state exported by :meth:`export_warm_state` into
        this (fresh) engine: pinned chains re-index under newly allocated
        pages, their saved K/V scatters back into the device pool (zero
        recompute — a returning tenant adopts them exactly as before the
        power loss), and the immune memories resume their EMAs. Returns the
        number of pinned pages restored."""
        if meta.get("pin_memory") is not None:
            self.alloc.set_pin_memory_state(meta["pin_memory"])
        if self.admission is not None and meta.get("admission"):
            self.admission.import_state(meta["admission"])
        placed = self.alloc.import_pinned(meta.get("forest") or [])
        per = int(meta.get("kv_per_page") or 0)
        if not placed or not per:
            return len(placed)
        pages = jnp.asarray([p for _, p in placed])
        stacks = [jnp.asarray(np.stack([kv[i * per + j] for i, _ in placed],
                                       axis=1))
                  for j in range(per)]           # (reps, n, page, Hkv, D)
        lane = iter(range(per))

        def scatter(kind, leaf):
            if kind in ("attn", "moe"):
                jk, jv = next(lane), next(lane)
                return {"k": leaf["k"].at[:, pages].set(stacks[jk]),
                        "v": leaf["v"].at[:, pages].set(stacks[jv])}
            return leaf

        self.pool = {"layers": transformer.map_block_caches(
            self.cfg, scatter, self.pool["layers"]), "pos": self.pool["pos"]}
        return len(placed)

    def _gather_page_kv(self, page: int) -> list:
        """Host copies of one physical page's K/V across every paged layer
        (k then v per layer, segment order) — the snapshot payload for one
        pinned page."""
        out: list[np.ndarray] = []

        def gather(kind, leaf):
            if kind in ("attn", "moe"):
                out.append(np.asarray(leaf["k"][:, page]))
                out.append(np.asarray(leaf["v"][:, page]))
            return leaf

        transformer.map_block_caches(self.cfg, gather, self.pool["layers"])
        return out
