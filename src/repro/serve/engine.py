"""Continuous-batching serving engine governed by the paper's immune primitives.

``serve.decode.generate`` serves a *fixed* batch: every prompt prefills together
and every sequence decodes in lockstep until the longest finishes. Real traffic
is an open-loop arrival process, so the engine keeps a fixed pool of decode
**slots** and admits requests mid-stream: a free slot is prefilled (batch-of-1)
and spliced into the pooled KV cache while the other slots keep decoding;
finished sequences retire and their slot is compacted (reset) for reuse. All
slot state is arrays (per-slot cache position, last token, active mask), so one
compiled decode step serves every tick regardless of occupancy.

Admission is the immune loop applied to serving, per the anticipation argument
of Boulmier et al. (PAPERS.md) — schedule on *remembered* cost, not
instantaneous load:

  * ``ImmuneMemory``      — EMA of per-request-class decode cost (slot-ticks);
                            admission orders candidates by remembered cost, so
                            a class's history, not the current queue snapshot,
                            decides who gets a slot under pressure.
  * ``TwoStageRegulator`` — admission-burst throttle: a burst admits at full
                            speed (fast response), the suppressor population
                            then builds and pauses follow-on admissions
                            (delayed negative feedback), damping convoys.
  * ``AnergyGate``        — request classes that repeatedly blow their latency
                            budget without co-stimulation (in-budget
                            completions) become anergic and are shed (left in
                            the queue, not admitted); an IL-2-like signal
                            revives them when queue pressure drops.

The FIFO policy (``EngineConfig(policy="fifo")``) is the baseline the
benchmark compares against.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core import immune
from ..models import model
from .decode import greedy

Array = jax.Array


# ---------------------------------------------------------------------------
# request / config types
# ---------------------------------------------------------------------------
@dataclass
class Request:
    """One serving request. ``tokens`` is the prompt; ``rclass`` buckets requests
    into the classes the immune admission controller remembers (e.g. endpoint,
    tenant, or prompt-shape bucket)."""

    rid: int
    tokens: np.ndarray                  # (L,) int32 prompt
    max_new_tokens: int
    rclass: int = 0
    arrival: int = 0                    # tick the request enters the queue
    eos_id: Optional[int] = None
    patches: Optional[np.ndarray] = None   # vlm prefix embeddings (P, Fd)
    frames: Optional[np.ndarray] = None    # audio frame embeddings (L, Fd)

    # filled in by the engine
    out_tokens: list = field(default_factory=list)
    admit_tick: int = -1
    finish_tick: int = -1
    slot: int = -1

    @property
    def latency(self) -> int:
        return self.finish_tick - self.arrival

    def prompts(self) -> dict:
        """The prefill batch-of-1 for this request — the single source of truth
        for what the engine feeds the model (the parity oracle reuses it)."""
        p = {"tokens": jnp.asarray(self.tokens, jnp.int32)[None]}
        if self.patches is not None:
            p["patches"] = jnp.asarray(self.patches)[None]
        if self.frames is not None:
            p["frames"] = jnp.asarray(self.frames)[None]
        return p


def attach_modality_inputs(req: Request, cfg: ModelConfig, rng) -> Request:
    """Give a request the frontend inputs its family needs (random stand-ins
    for the stub frontends) — shared by the trace generator, the examples, and
    the tests so the shapes can't drift apart."""
    if cfg.family == "vlm":
        req.patches = rng.standard_normal(
            (cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
    if cfg.family == "audio":
        req.frames = rng.standard_normal(
            (len(req.tokens), cfg.frontend_dim)).astype(np.float32)
    return req


class EngineConfig(NamedTuple):
    num_slots: int = 4
    max_cache: int = 96
    policy: str = "immune"            # "immune" | "fifo"
    num_classes: int = 4
    latency_budget: float = 32.0      # ticks; beyond this a completion "blew" SLO
    mem_decay: float = 0.8            # cost-memory EMA decay
    reg_threshold: float = 2.0        # admission pauses while response exceeds this
    shed_level: float = 0.5           # anergy level above which a class is shed
    low_pressure: float = 0.5         # queue_len < low_pressure*num_slots -> IL-2
    anergy_onset: float = 0.34
    anergy_revival: float = 0.3


# ---------------------------------------------------------------------------
# jitted slot-pool kernels — shared across Engine instances via jit's cache
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames=("cfg", "max_cache"))
def _prefill_one(params, cfg: ModelConfig, prompts: dict, max_cache: int,
                 router_bias):
    """Prefill a batch-of-1 prompt into a fresh cache; returns (first_token,
    cache). Identical math to the first stage of ``decode.generate``."""
    cache = model.init_cache(cfg, 1, max_cache)
    logits, cache = model.prefill(params, cfg, prompts, cache,
                                  router_bias=router_bias)
    return greedy(logits), cache


@partial(jax.jit, donate_argnums=(0, 3))
def _splice(pool, one, slot, last, active, first):
    """Insert a prefilled batch-of-1 cache + its first token into ``slot``."""
    pool = model.insert_slot_cache(pool, one, slot)
    return pool, last.at[slot].set(first[0]), active.at[slot].set(True)


@partial(jax.jit, donate_argnums=(0,))
def _release(pool, active, slot):
    """Retire ``slot``: compact (zero) its cache row and clear the active bit."""
    return model.reset_slot_cache(pool, slot), active.at[slot].set(False)


# pool and last are donated: the engine rebinds both from the return value each
# tick, and without donation every decoded token would pay a fresh copy of the
# whole pooled KV cache (the scan carry in decode._decode_loop gets this free)
@partial(jax.jit, static_argnames=("cfg",), donate_argnums=(2, 3))
def _decode_tick(params, cfg: ModelConfig, pool, last, active, router_bias,
                 frames):
    """One token for every slot (occupied or not) — the single compiled decode
    step. Inactive slots advance neither position nor last token; their lane
    computes a garbage token that the host discards, which is what keeps the
    step shape (and therefore the compiled program) independent of occupancy."""
    batch = {"token": last}
    if cfg.family == "audio":
        batch["frame"] = frames
    logits, new_pool = model.decode_step(params, cfg, batch, pool,
                                         router_bias=router_bias)
    nxt = greedy(logits)                             # (S, 1)
    pos = jnp.where(active, new_pool["pos"], pool["pos"])
    last = jnp.where(active[:, None], nxt, last)
    return nxt, last, {"layers": new_pool["layers"], "pos": pos}


# ---------------------------------------------------------------------------
# immune admission controller
# ---------------------------------------------------------------------------
class ImmuneAdmission:
    """Host-side admission controller over the three immune primitives.

    Per tick: completions feed the cost memory and the anergy
    stimulus/co-stimulus counters; ``end_tick`` advances the regulator (with the
    tick's admissions as stimulus) and the anergy gate (with IL-2 flowing when
    queue pressure is low)."""

    def __init__(self, ecfg: EngineConfig):
        self.ecfg = ecfg
        c = ecfg.num_classes
        self.memory = immune.ImmuneMemory.create((c,), decay=ecfg.mem_decay)
        self.regulator = immune.TwoStageRegulator.create()
        self.reg_state = self.regulator.init(())
        self.gate = immune.AnergyGate.create(onset=ecfg.anergy_onset,
                                             revival=ecfg.anergy_revival)
        self.anergy = self.gate.init((c,))
        self._blown = np.zeros(c, np.float32)
        self._ok = np.zeros(c, np.float32)

    def remembered_cost(self, rclass: int) -> float:
        return float(self.memory.value[rclass])

    def observe_completion(self, rclass: int, cost: float, latency: float):
        # per-class EMA: observing `value` for the untouched classes leaves them
        # unchanged under ImmuneMemory's decay*v + (1-decay)*obs update
        self.memory = self.memory.update(
            self.memory.value.at[rclass].set(cost))
        if latency > self.ecfg.latency_budget:
            self._blown[rclass] += 1.0
        else:
            self._ok[rclass] += 1.0

    def admissible(self, rclass: int) -> bool:
        return float(self.anergy.level[rclass]) <= self.ecfg.shed_level

    def throttled(self) -> bool:
        return float(self.reg_state.response) > self.ecfg.reg_threshold

    def end_tick(self, admitted: int, queue_len: int,
                 queued_demand: np.ndarray, predicted_cost: np.ndarray):
        """Advance the regulator and anergy gate one tick.

        Anergy stimulus is anticipatory: a class with queued demand whose
        predicted cost already exceeds the latency budget *will* blow its SLO —
        that is antigen without co-stimulation, and waiting for the completions
        to prove it would let the convoy form first. In-budget completions are
        the co-stimulation; IL-2 flows when queue pressure drops, reviving shed
        classes so they are served in quiet periods."""
        stim = jnp.asarray(admitted / max(self.ecfg.num_slots, 1), jnp.float32)
        self.reg_state = self.regulator.step(self.reg_state, stim)
        il2 = 1.0 if queue_len < self.ecfg.low_pressure * self.ecfg.num_slots \
            else 0.0
        will_blow = (queued_demand > 0) & \
            (predicted_cost > self.ecfg.latency_budget)
        self.anergy = self.gate.step(
            self.anergy,
            stimulus=jnp.asarray((self._blown > 0) | will_blow, jnp.float32),
            costimulus=jnp.asarray(self._ok > 0, jnp.float32),
            il2=il2)
        self._blown[:] = 0.0
        self._ok[:] = 0.0


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
class Engine:
    """Continuous-batching decode over a fixed slot pool with queue admission."""

    def __init__(self, params, cfg: ModelConfig, ecfg: EngineConfig,
                 router_bias: Optional[Array] = None):
        self.params, self.cfg, self.ecfg = params, cfg, ecfg
        self.router_bias = router_bias
        # MoE: the decode tick runs every slot, occupied or not, and expert
        # capacity is contended across whatever shares the batch — a garbage
        # lane from an empty slot must never displace a real request's token.
        # Bump the decode-path capacity so the (tiny: num_slots * k) token set
        # is dropless by construction. Prefill keeps the configured capacity:
        # it is a batch-of-1 call, bitwise-identical to one-shot generate's.
        self.cfg_decode = cfg if not cfg.num_experts else dataclasses.replace(
            cfg, capacity_factor=float(max(cfg.num_experts,
                                           cfg.capacity_factor)))
        s = ecfg.num_slots
        self.pool = model.init_slot_cache(cfg, s, ecfg.max_cache)
        self.last = jnp.zeros((s, 1), jnp.int32)
        self.active = jnp.zeros((s,), bool)
        self.frames = (jnp.zeros((s, 1, cfg.frontend_dim), jnp.float32)
                       if cfg.family == "audio" else None)
        self.slots: list[Optional[Request]] = [None] * s
        self.queue: deque[Request] = deque()
        self.tick = 0
        self.completed: list[Request] = []
        self.shed: list[Request] = []      # rejected while their class was anergic
        self.admission = ImmuneAdmission(ecfg) if ecfg.policy == "immune" \
            else None
        self.mid_stream_admissions = 0     # admissions while other slots decode
        self.unsubmitted = 0               # run() arrivals never reached
        self._admitted_this_tick = 0
        self._decoding_before_admit = False

    # -- queue ---------------------------------------------------------------
    def submit(self, req: Request):
        need = len(req.tokens) + self.cfg.frontend_tokens + req.max_new_tokens
        if need > self.ecfg.max_cache:
            raise ValueError(
                f"request {req.rid}: prompt+prefix+decode = {need} exceeds "
                f"max_cache = {self.ecfg.max_cache}")
        if self.admission is not None and not 0 <= req.rclass < \
                self.ecfg.num_classes:
            raise ValueError(f"request {req.rid}: rclass {req.rclass} outside "
                             f"[0, {self.ecfg.num_classes})")
        self.queue.append(req)

    # -- admission -----------------------------------------------------------
    def _admit_into(self, req: Request, slot: int):
        first, one = _prefill_one(self.params, self.cfg, req.prompts(),
                                  self.ecfg.max_cache, self.router_bias)
        if self._decoding_before_admit:
            self.mid_stream_admissions += 1
        self.pool, self.last, self.active = _splice(
            self.pool, one, jnp.asarray(slot), self.last, self.active, first)
        req.slot, req.admit_tick = slot, self.tick
        req.out_tokens.append(int(first[0, 0]))
        self.slots[slot] = req
        self._admitted_this_tick += 1

    def _admit(self):
        self._admitted_this_tick = 0
        # mid-stream means spliced in while another slot was actually decoding
        # — slots filled earlier in this same admission pass don't count
        self._decoding_before_admit = any(r is not None for r in self.slots)
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.queue:
            return
        if self.admission is None:                      # FIFO baseline
            while free and self.queue:
                self._admit_into(self.queue.popleft(), free.pop(0))
            return
        adm = self.admission
        # tolerance turned shedding: requests of anergic classes are rejected
        # outright (not parked — a parked convoy would hold queue pressure high
        # and block the IL-2 revival it is waiting for)
        for req in [r for r in self.queue if not adm.admissible(r.rclass)]:
            self.queue.remove(req)
            self.shed.append(req)
        if adm.throttled():                             # delayed suppression
            return
        # anticipation: order by *remembered* class cost, not queue position
        cost = self._predicted_costs()
        candidates = sorted(self.queue,
                            key=lambda r: (cost[r.rclass], r.arrival, r.rid))
        for req in candidates[:len(free)]:
            self.queue.remove(req)
            self._admit_into(req, free.pop(0))

    def _predicted_costs(self) -> np.ndarray:
        """Per-class cost estimate: the EMA memory, floored by what currently
        running requests have already revealed (ticks held so far is a lower
        bound on their class's true cost). Without the reveal, the cold-start
        memory is all zeros and the first burst of heavies convoys the pool."""
        cost = np.asarray(self.admission.memory.value, np.float64).copy()
        for r in self.slots:
            if r is not None:
                cost[r.rclass] = max(cost[r.rclass], self.tick - r.admit_tick)
        return cost

    # -- retirement ----------------------------------------------------------
    def _finished(self, req: Request) -> bool:
        if len(req.out_tokens) >= req.max_new_tokens:
            return True
        return req.eos_id is not None and req.out_tokens and \
            req.out_tokens[-1] == req.eos_id

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None or not self._finished(req):
                continue
            req.finish_tick = self.tick
            self.completed.append(req)
            self.slots[slot] = None
            self.pool, self.active = _release(self.pool, self.active,
                                              jnp.asarray(slot))
            if self.admission is not None:
                # cost = slot-ticks consumed; feeds the anticipation memory
                self.admission.observe_completion(
                    req.rclass, cost=float(len(req.out_tokens)),
                    latency=float(req.latency))

    # -- one tick ------------------------------------------------------------
    def step(self):
        """One engine tick: admit into free slots, decode one token for every
        occupied slot, retire finished sequences, advance the immune states."""
        self._admit()
        if any(r is not None for r in self.slots):
            nxt, self.last, self.pool = _decode_tick(
                self.params, self.cfg_decode, self.pool, self.last, self.active,
                self.router_bias, self.frames)
            nxt_host = np.asarray(nxt[:, 0])
            for slot, req in enumerate(self.slots):
                if req is not None and not self._finished(req):
                    req.out_tokens.append(int(nxt_host[slot]))
        self._retire()
        if self.admission is not None:
            demand = np.zeros(self.ecfg.num_classes, np.float64)
            for r in self.queue:
                demand[r.rclass] += 1.0
            self.admission.end_tick(self._admitted_this_tick, len(self.queue),
                                    demand, self._predicted_costs())
        self.tick += 1

    # -- driver --------------------------------------------------------------
    def run(self, requests: list[Request], max_ticks: int = 10_000) -> dict:
        """Open-loop drive: submit each request at its ``arrival`` tick, run
        until everything completes (or ``max_ticks``); returns ``stats()``."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        while True:
            while i < len(pending) and pending[i].arrival <= self.tick:
                self.submit(pending[i])
                i += 1
            drained = (i == len(pending) and not self.queue
                       and all(r is None for r in self.slots))
            if drained or self.tick >= max_ticks:
                break
            self.step()
        # arrivals the max_ticks backstop never let in still count as demand —
        # otherwise a policy that stalls into the backstop flatters its stats
        self.unsubmitted = len(pending) - i
        return self.stats()

    def stats(self) -> dict:
        lat = np.asarray([r.latency for r in self.completed], np.float64)
        toks = int(sum(len(r.out_tokens) for r in self.completed))
        in_budget = int((lat <= self.ecfg.latency_budget).sum()) if lat.size \
            else 0
        in_flight = sum(r is not None for r in self.slots)
        # every request the trace produced, wherever it ended up — the goodput
        # denominator, so a policy that stalls into the max_ticks backstop
        # (requests still queued, in-flight, or never submitted) cannot
        # flatter itself by under-counting demand
        demand = (len(self.completed) + len(self.shed) + len(self.queue)
                  + in_flight + self.unsubmitted)
        # no completions -> the tail is unbounded, not "best ever"
        empty = float("inf")
        return {
            "policy": self.ecfg.policy,
            "ticks": self.tick,
            "completed": len(self.completed),
            "shed": len(self.shed),
            "unserved": len(self.queue) + in_flight + self.unsubmitted,
            "tokens": toks,
            "throughput": toks / max(self.tick, 1),
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else empty,
            "p99_latency": float(np.percentile(lat, 99)) if lat.size else empty,
            "max_latency": float(lat.max()) if lat.size else empty,
            # fraction of total demand served within the latency budget: shed
            # requests count against goodput — rejection is not a free lunch
            "goodput": in_budget / max(demand, 1),
            "mid_stream_admissions": self.mid_stream_admissions,
        }


# ---------------------------------------------------------------------------
# synthetic open-loop traffic
# ---------------------------------------------------------------------------
def synthetic_trace(cfg: ModelConfig, num_requests: int = 40, seed: int = 0,
                    burst_every: int = 10, burst_size: int = 8,
                    light_tokens: int = 5, heavy_tokens: int = 40,
                    heavy_frac: float = 0.15,
                    prompt_lens: tuple = (8, 16)) -> list[Request]:
    """Bursty heterogeneous arrivals: mostly light requests plus a heavy class
    whose decode length alone blows a chat-style latency budget. Classes:
    0..len(prompt_lens)-1 are light (one per prompt-length bucket); the last
    class is heavy. Prompt lengths come from a tiny bucket set so the engine
    compiles a bounded number of prefill shapes."""
    rng = np.random.default_rng(seed)
    reqs = []
    n_light_classes = len(prompt_lens)
    for rid in range(num_requests):
        burst = rid // burst_size
        heavy = rng.random() < heavy_frac
        plen = int(prompt_lens[rid % n_light_classes])
        rclass = n_light_classes if heavy else rid % n_light_classes
        steps = heavy_tokens if heavy else light_tokens + rid % 3
        req = Request(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=int(steps),
            rclass=rclass,
            arrival=burst * burst_every + int(rng.integers(0, 3)),
        )
        reqs.append(attach_modality_inputs(req, cfg, rng))
    return reqs
