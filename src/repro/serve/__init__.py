from . import api, decode, engine, faults, paging, router, traces  # noqa: F401
