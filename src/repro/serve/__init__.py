from . import decode  # noqa: F401
