from . import api, decode, engine, paging, traces  # noqa: F401
