from . import api, decode, engine, paging, router, traces  # noqa: F401
