"""Batched serving: prefill + greedy/sampled decode over a fixed slot batch.

``serve_step`` (one token for the whole batch against the KV cache) is the function
the decode-shape dry-runs lower; ``generate`` is the end-to-end driver used by the
serving example (prefill once, then N decode steps under jit).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model

Array = jax.Array


class ServeState(NamedTuple):
    cache: dict
    tokens: Array        # (B, T_out) generated so far
    last: Array          # (B, 1) last emitted token


def serve_step(params, cfg: ModelConfig, batch: dict, cache: dict,
               router_bias: Optional[Array] = None):
    """One new token per sequence with a KV cache — the decode dry-run target."""
    return model.decode_step(params, cfg, batch, cache, router_bias=router_bias)


def greedy(logits: Array) -> Array:
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


@partial(jax.jit, static_argnames=("cfg", "steps"))
def _decode_loop(params, cfg: ModelConfig, first_token: Array, cache: dict,
                 steps: int, router_bias=None, frames=None):
    def body(carry, t):
        tok, cache = carry
        batch = {"token": tok}
        if cfg.family == "audio":
            batch["frame"] = frames[:, t][:, None]
        logits, cache = serve_step(params, cfg, batch, cache,
                                   router_bias=router_bias)
        nxt = greedy(logits)
        return (nxt, cache), nxt[:, 0]

    (_, cache), toks = jax.lax.scan(body, (first_token, cache),
                                    jnp.arange(steps))
    return jnp.moveaxis(toks, 0, 1), cache           # (B, steps)


def generate(params, cfg: ModelConfig, prompts: dict, max_cache: int, steps: int,
             router_bias: Optional[Array] = None):
    """Prefill the prompt batch, then greedily decode ``steps`` tokens."""
    b = prompts["tokens"].shape[0]
    cache = model.init_cache(cfg, b, max_cache)
    logits, cache = model.prefill(params, cfg, prompts, cache,
                                  router_bias=router_bias)
    first = greedy(logits)
    frames = None
    if cfg.family == "audio":
        frames = jnp.zeros((b, steps, cfg.frontend_dim),
                           prompts["frames"].dtype)
    toks, cache = _decode_loop(params, cfg, first, cache, steps,
                               router_bias=router_bias, frames=frames)
    return jnp.concatenate([first, toks[:, :-1]], axis=1), cache
