"""Batched serving: prefill + greedy/sampled decode over a fixed slot batch.

``serve_step`` (one token for the whole batch against the KV cache) is the function
the decode-shape dry-runs lower; ``generate`` is the end-to-end driver behind the
one-shot side of the serving API (``serve.api.generate`` wraps it per request).

``sampling`` (a ``models.model.SamplingSpec`` of per-lane arrays) switches the
loop from argmax to the masked top-k/top-p sampling lane — the *same*
``model.sample_tokens`` the engine's compiled decode tick runs, with the same
key discipline (lane key folded with the index of the token being emitted), so
seeded output here is bitwise what the engine emits for the same request.
``return_logits`` additionally returns every emitted token's pre-sampling
logits row — the logits-level parity oracle the engine is checked against.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model

Array = jax.Array


class ServeState(NamedTuple):
    cache: dict
    tokens: Array        # (B, T_out) generated so far
    last: Array          # (B, 1) last emitted token


def serve_step(params, cfg: ModelConfig, batch: dict, cache: dict,
               router_bias: Optional[Array] = None):
    """One new token per sequence with a KV cache — the decode dry-run target."""
    return model.decode_step(params, cfg, batch, cache, router_bias=router_bias)


def greedy(logits: Array) -> Array:
    return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)


def null_spec(batch: int) -> model.SamplingSpec:
    """All-greedy placeholder lanes (traced but unused when not sampling)."""
    return model.SamplingSpec(
        keys=jnp.zeros((batch, 2), jnp.uint32),
        temperature=jnp.zeros((batch,), jnp.float32),
        top_k=jnp.zeros((batch,), jnp.int32),
        top_p=jnp.ones((batch,), jnp.float32),
        rep_penalty=jnp.ones((batch,), jnp.float32),
        pres_penalty=jnp.zeros((batch,), jnp.float32),
        freq_penalty=jnp.zeros((batch,), jnp.float32))


@jax.jit
def _sample_first(logits, spec):
    """The prompt's last-position logits seed decoding: token index 0, so the
    lane key folds with 0 — exactly what the engine does at slot activation."""
    return model.sample_tokens(logits, spec, 0)


@partial(jax.jit,
         static_argnames=("cfg", "steps", "do_sample", "return_logits",
                          "return_logprobs", "use_penalties", "return_topk"))
def _decode_loop(params, cfg: ModelConfig, first_token: Array, cache: dict,
                 steps: int, spec: model.SamplingSpec, router_bias=None,
                 frames=None, do_sample: bool = False,
                 return_logits: bool = False, return_logprobs: bool = False,
                 use_penalties: bool = False, return_topk: int = 0):
    b = first_token.shape[0]
    rows = jnp.arange(b)
    counts0 = jnp.zeros((b, cfg.vocab_size), jnp.int32)
    if use_penalties:
        # the prefill-seeded first token is already emitted when the loop's
        # first draw happens — count it (the seed draw itself saw zero counts)
        counts0 = counts0.at[rows, first_token[:, 0]].add(1)

    def body(carry, t):
        tok, cache, counts = carry
        batch = {"token": tok}
        if cfg.family == "audio":
            batch["frame"] = frames[:, t][:, None]
        logits, cache = serve_step(params, cfg, batch, cache,
                                   router_bias=router_bias)
        # token t of the loop is emitted token t+1 overall (the prefill-seeded
        # first token is index 0) — the fold_in index both backends agree on
        nxt = model.sample_tokens(logits, spec, t + 1,
                                  counts=counts if use_penalties else None) \
            if do_sample else greedy(logits)
        if use_penalties:
            counts = counts.at[rows, nxt[:, 0]].add(1)
        out = {"tok": nxt[:, 0]}
        if return_logits:
            out["logits"] = logits[:, -1]
        if return_logprobs:
            out["lp"] = model.chosen_logprob(logits, nxt)[:, 0]
        if return_topk:
            lp_full = jax.nn.log_softmax(logits[:, -1].astype(jnp.float32))
            out["tl_v"], out["tl_i"] = jax.lax.top_k(lp_full, return_topk)
        return (nxt, cache, counts), out

    (_, cache, _), outs = jax.lax.scan(body, (first_token, cache, counts0),
                                       jnp.arange(steps))
    toks = jnp.moveaxis(outs["tok"], 0, 1)               # (B, steps)
    lseq = jnp.moveaxis(outs["logits"], 0, 1) if return_logits else None
    lpseq = jnp.moveaxis(outs["lp"], 0, 1) if return_logprobs else None
    tkseq = (jnp.moveaxis(outs["tl_v"], 0, 1),
             jnp.moveaxis(outs["tl_i"], 0, 1)) if return_topk else None
    return toks, cache, lseq, lpseq, tkseq


def generate(params, cfg: ModelConfig, prompts: dict, max_cache: int, steps: int,
             router_bias: Optional[Array] = None,
             sampling: Optional[model.SamplingSpec] = None,
             return_logits: bool = False, return_logprobs: bool = False,
             use_penalties: bool = False, return_topk: int = 0):
    """Prefill the prompt batch, then decode ``steps`` tokens — argmax by
    default, per-lane sampled under ``sampling``. Returns ``(tokens, cache)``,
    plus the per-token logits rows ``(B, steps, V)`` when ``return_logits``,
    plus each chosen token's raw-distribution logprob ``(B, steps)`` when
    ``return_logprobs``, plus ``(values, ids)`` top-``return_topk``
    alternative logprobs ``(B, steps, k)`` when requested (always last).

    ``use_penalties`` threads a per-lane emitted-token count table through the
    loop so ``sampling``'s repetition/presence/frequency rows bite; requires
    ``sampling`` (greedy-with-penalties is a temperature-0 spec lane)."""
    b = prompts["tokens"].shape[0]
    cache = model.init_cache(cfg, b, max_cache)
    logits0, cache = model.prefill(params, cfg, prompts, cache,
                                   router_bias=router_bias)
    first = greedy(logits0) if sampling is None \
        else _sample_first(logits0, sampling)
    frames = None
    if cfg.family == "audio":
        frames = jnp.zeros((b, steps, cfg.frontend_dim),
                           prompts["frames"].dtype)
    toks, cache, lseq, lpseq, tkseq = _decode_loop(
        params, cfg, first, cache, steps,
        sampling if sampling is not None else null_spec(b),
        router_bias=router_bias, frames=frames,
        do_sample=sampling is not None, return_logits=return_logits,
        return_logprobs=return_logprobs,
        use_penalties=use_penalties and sampling is not None,
        return_topk=return_topk)
    out = (jnp.concatenate([first, toks[:, :-1]], axis=1), cache)
    if return_logits:
        out = out + (jnp.concatenate([logits0, lseq[:, :-1]], axis=1),)
    if return_logprobs:
        lp0 = model.chosen_logprob(logits0, first)[:, 0:1]    # (B, 1)
        out = out + (jnp.concatenate([lp0, lpseq[:, :-1]], axis=1),)
    if return_topk:
        lp0_full = jax.nn.log_softmax(logits0[:, -1].astype(jnp.float32))
        tv0, ti0 = jax.lax.top_k(lp0_full, return_topk)
        tv = jnp.concatenate([tv0[:, None], tkseq[0][:, :-1]], axis=1)
        ti = jnp.concatenate([ti0[:, None], tkseq[1][:, :-1]], axis=1)
        out = out + ((tv, ti),)
    return out
