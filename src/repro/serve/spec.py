"""Self-speculative decoding: truncated-depth draft + batched paged verify.

One spec tick replaces up to ``k + 1`` sequential decode ticks:

  draft   — ``k`` greedy one-token steps through only the first
            ``depth`` layer repetitions of the *same* weights
            (``transformer.truncate_stack``: layer d's input depends only on
            layers < d, so the paged pool's leading-``depth`` K/V slice *is*
            the truncated model's cache — there are no draft weights and no
            persistent draft cache). The draft writes K/V into a sliced
            functional copy of the pool that is simply discarded, so nothing
            it does is observable — it only has to be *cheap* and *often
            right*, never correct.
  verify  — one ``model.verify_step``: rows ``[last, d_1..d_k]`` scored at
            positions ``pos..pos+k`` through the full stack. Row ``j``'s
            logits are bitwise the logits sequential decode would produce
            after emitting ``j`` of the drafted tokens (same per-row gather +
            ``_sdpa`` contraction, dropless MoE ⇒ row-count invariance), which
            is what makes greedy accept/reject a *bitwise* oracle rather than
            a statistical one: the engine accepts the longest prefix with
            ``d_j == argmax(row j-1)`` plus the bonus token ``argmax(row a)``,
            and the emitted stream is exactly the non-speculative stream.

The engine gates spec ticks to all-greedy resident batches (sampled lanes
fold PRNG keys per emitted index — a multi-token tick has no single key),
no penalties/logprobs capture, attention/MoE stacks. A MoE engine's router
bias rides into both draft and verify — verify routes with exactly the
plain tick's bias, so the bitwise contract is unaffected.

``make_draft_friendly`` is the test/bench utility that makes a random init
behave like a trained model for acceptance purposes: scaling the deep layers'
residual write-back projections toward zero leaves ``x_depth ≈ x_L`` so the
truncated head agrees with the full head often, without touching the
verify-side bitwise contract (parity holds at any acceptance rate).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model, transformer

Array = jax.Array


@partial(jax.jit,
         static_argnames=("cfg", "k", "depth", "attn_backend", "return_logits"))
def spec_tick(params, cfg: ModelConfig, pool: dict, last: Array, active: Array,
              table: Array, k: int, depth: int, attn_backend: str = "xla",
              return_logits: bool = False,
              router_bias: Optional[Array] = None):
    """One fused draft+verify tick over the whole slot batch.

    ``last`` (B, 1) is each slot's newest emitted token (its K/V not yet
    written — the engine's position invariant), ``pool["pos"]`` its cache
    position. ``router_bias`` is the engine's MoE selection bias: the verify
    pass routes with it exactly as the plain decode tick does (the bitwise
    contract), and the truncated draft takes its leading layers' rows.
    Returns ``(drafts (B, k), argmax (B, k+1), ok (B,),
    logits (B, k+1, V) | None, new_pool)``; the host accept loop owns token
    emission and position advancement."""
    d_stack = transformer.truncate_stack(params["stack"], depth)
    d_caches = transformer.truncate_stack(pool["layers"], depth)

    def body(carry, _):
        tok, caches, posv = carry
        x = model._embed(params, cfg, tok)
        x, caches = transformer.apply_stack_decode(
            d_stack, x, cfg, caches, posv, bias=router_bias, table=table,
            active=active, attn_backend=attn_backend)
        lg = model._head(params, cfg, x)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return (nxt, caches, posv + 1), nxt[:, 0]

    (_, _, _), drafts = jax.lax.scan(
        body, (last, d_caches, pool["pos"]), None, length=k)
    drafts = jnp.moveaxis(drafts, 0, 1)                       # (B, k)

    seq = jnp.concatenate([last, drafts], axis=1)             # (B, k+1)
    logits, new_pool = model.verify_step(
        params, cfg, {"tokens": seq}, pool, table, active=active,
        attn_backend=attn_backend, router_bias=router_bias)
    am = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # (B, k+1)
    ok = jnp.isfinite(logits).all(axis=(1, 2))                # (B,)
    return drafts, am, ok, (logits if return_logits else None), new_pool


def accept_length(drafts, argmaxes, k: int) -> int:
    """Host-side greedy accept rule for one lane: the longest prefix of the
    ``k`` drafts where ``d_j == argmax(row j-1)``. The lane then emits that
    prefix plus the bonus token ``argmax(row a)`` — ``a + 1`` tokens total,
    each bitwise what sequential greedy decode would have emitted."""
    a = 0
    while a < k and int(drafts[a]) == int(argmaxes[a]):
        a += 1
    return a


def make_draft_friendly(params: dict, cfg: ModelConfig, depth: int,
                        scale: float = 0.05) -> dict:
    """Scale the residual write-back projections (``wo``, ``w_down``) of every
    layer repetition >= ``depth`` toward zero, so the deep layers barely move
    the residual stream and the truncated-depth draft's argmax usually agrees
    with the full model's. Random inits have ~chance acceptance otherwise;
    this stands in for the trained-model property that late layers refine
    rather than rewrite. Sampling/verify semantics are untouched — it returns
    an ordinary parameter tree."""
    def rescale(path, leaf):
        name = getattr(path[-1], "key", None) if path else None
        if name in ("wo", "w_down") and getattr(leaf, "ndim", 0) >= 1:
            reps = leaf.shape[0]
            mask = (jnp.arange(reps) >= depth).reshape(
                (reps,) + (1,) * (leaf.ndim - 1))
            return jnp.where(mask, (leaf.astype(jnp.float32)
                                    * scale).astype(leaf.dtype), leaf)
        return leaf
    stack = jax.tree_util.tree_map_with_path(rescale, params["stack"])
    return {**params, "stack": stack}
