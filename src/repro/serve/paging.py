"""Host-side block-table allocator for the paged KV cache (vLLM-style), with
**refcounted prefix sharing**, copy-on-write forking, and a **persistent pinned
prefix cache**.

The device holds one physical page pool per attention layer, shaped
``(num_pages, page_size, kv_heads, head_dim)``; this module owns the *mapping*:
which physical pages belong to which decode slot, in logical order. The device
side never sees the free list — only the dense ``(num_slots, max_pages_per_slot)``
block table produced by :meth:`PageAllocator.table`.

Ownership is **refcounted**: a physical page may appear in several slots' block
tables at once when those slots share a prompt prefix. A host-side **prefix
index** maps chains of *full pages of prompt token ids* to the physical page
already holding their K/V: admission walks the new prompt's pages through the
index and adopts every hit with ``refcount++`` instead of reserving and
re-prefilling it (``adopt``). K/V at a position is a pure function of the token
prefix for text-only stacks, so adopted pages are bitwise what the request's
own prefill would have written — the caller gates sharing to such configs. The
index is keyed by (interned chain-prefix id, full page token tuple) — content
equality, not hashing — so a chain hit can never be a collision.

A shared page is immutable to its adopters, with one exception: a write whose
value is bitwise identical to what the page already holds (the engine's
no-write full-last-page adoption) is indistinguishable from no write at all.
When a slot must write *divergent* data into one — the unshared tail of its
prompt starts mid-page after a partial-page hit — it **copy-on-write forks** it
first (``cow_fork``): a fresh page replaces the shared one in this slot's
chain, the shared page's refcount drops, and the caller copies the shared
prefix entries on device before writing. A fork target never aliases a
still-shared page.

**Pinned prefix cache** (``pin_pages > 0``): the prefix index is a *cache*, not
just a rendezvous for concurrently-live requests. When an indexed page's
refcount hits zero it is not freed — it is *pinned*: kept resident and indexed,
charged to the ``pin_pages`` budget, so a returning tenant minutes later adopts
the chain exactly like a live shared one and re-prefills only its unique
suffix. Eviction is **immune-memory-weighted LRU**: each page is tagged with
the request class that last touched it, a per-class :class:`~repro.core.immune.
ImmuneMemory` EMA tracks how many pages each class's admissions actually adopt
(its remembered prefix value), and under pressure the evictable pinned page
with the lowest ``(class value, last-use stamp)`` goes first. Only chain
*leaves* (no indexed children) are evictable, so eviction never strands a
reachable chain. Pressure comes from two places: the pin budget itself
(pinning a hotter page may evict a strictly colder one) and the free list
(``_take_page`` evicts pinned pages before giving up).

Layout invariants (the hypothesis suite in ``tests/test_paging.py`` churns these):

  * page 0 is the **null page**: never allocated, permanently parked. Unmapped
    block-table entries point at it, and the decode step routes the writes of
    inactive slots there, so it doubles as the trash page. Reads of it are
    always masked, so its contents are irrelevant as long as they stay finite.
  * ``sum(refcounts) == total live block-table entries`` — every owner of a
    page is counted, and nothing else is. Pinned pages have refcount zero and
    appear in no block table.
  * no page is ever simultaneously on the free list and refcounted, or on the
    free list and pinned. A page whose refcount hits zero is either pinned
    (indexed, budget permitting, chain reachable) or freed immediately and
    dropped from the prefix index — index entries only ever point at live or
    pinned pages, and every indexed page's parent chain is live or pinned.
  * ``free + pinned + distinct live pages == num_pages - 1`` (conservation,
    null page excluded — a shared page counts once, which is the memory win).
  * ``available()`` counts free *and* pinned pages (pinned pages are
    reclaimable on demand) net of reservations, and never goes negative.

Two admission disciplines share this allocator:

  * **reservation** (``require_reservation=True``): admission promises a
    request's private worst case up front (``reserve``), pages are appended
    lazily (``ensure``), and growing past the reservation is a bug. A slot can
    never stall mid-decode — the classic no-deadlock guarantee, paid for in
    admission pessimism.
  * **preemption** (``require_reservation=False``): no promises — ``ensure``
    and ``cow_fork`` draw pages on demand and raise :class:`OutOfPages` when
    the pool (free + evictable pinned) is exhausted. The engine resolves the
    stall by preempting a low-priority slot and replaying it later; the
    allocator only reports the pressure.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from ..core import immune

NULL_PAGE = 0


class OutOfPages(RuntimeError):
    """No free page and no evictable pinned page — the caller must preempt
    (or defer) to make progress. Only raised under ``require_reservation=False``;
    a reservation-mode allocator that hits this has broken its accounting."""


def pages_for(tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``tokens`` cache positions."""
    return -(-tokens // page_size)


class PageAllocator:
    """Refcounted free-list page allocator with per-slot reservations, a
    prefix-sharing index, and an optional pinned prefix cache.

    ``num_pages`` counts the null page, so ``num_pages - 1`` pages are usable.
    ``share_prefix=False`` disables the index (every page single-owner, the
    pre-sharing behavior) without changing any other semantics; ``pin_pages``
    (which requires the index) sets the persistent-cache budget, 0 restoring
    free-on-zero exactly.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_pages_per_slot: int, share_prefix: bool = True,
                 pin_pages: int = 0, num_classes: int = 1,
                 pin_decay: float = 0.8, require_reservation: bool = True):
        if num_pages < 2:
            raise ValueError("need at least one usable page beyond the null page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.share_prefix = share_prefix
        self.pin_pages = min(pin_pages, num_pages - 1) if share_prefix else 0
        self.num_classes = max(1, num_classes)
        self.require_reservation = require_reservation
        # pop() order is ascending page id — cosmetic, but makes traces readable
        self._free = list(range(num_pages - 1, NULL_PAGE, -1))
        self._owned: list[list[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros(num_slots, np.int64)
        self._ref = np.zeros(num_pages, np.int64)
        # prefix index: a page holding the i-th full page of a prompt is keyed
        # by (chain node id of pages 0..i-1, its own page_size token ids).
        # Node ids *intern* chain prefixes — one id per distinct content path,
        # assigned at registration — so a hit is still full-content equality
        # (never a hash collision), but each dict access hashes O(page_size)
        # instead of rehashing the whole nested prefix: index walks stay
        # linear in the prompt length. Node id 0 is the empty chain.
        self._index: dict[tuple, tuple] = {}    # (parent id, pt) -> (node, page)
        # partial-match candidates, bucketed by (parent node, first token) so
        # a busy divergence point (e.g. many distinct prompts under the root)
        # never costs a linear scan over all its children
        self._children: dict[tuple, set] = {}
        self._page_key: dict[int, tuple] = {}   # page id -> its index key
        # node id -> set of indexed child pages; a chain page is an evictable
        # *leaf* iff this set is empty for its node
        self._node_kids: dict[int, set] = {}
        self._next_node = 1
        # pinned cache state: refcount-zero indexed pages kept resident.
        self._pinned: set[int] = set()
        self._last_use = np.zeros(num_pages, np.int64)     # LRU stamps
        self._page_class = np.zeros(num_pages, np.int64)   # last adopter class
        self._clock = 0
        # per-class remembered prefix value: EMA of pages adopted per admission
        # — the immune-memory weight in the eviction score
        self.pin_memory = immune.ImmuneMemory.create((self.num_classes,),
                                                     decay=pin_decay)
        self._class_w = np.asarray(self.pin_memory.value)
        self.high_water = 0
        self.cow_forks = 0
        self.pins = 0            # refcount-zero pages retained in the cache
        self.pinned_hits = 0     # pinned pages revived by adoption
        self.evictions = 0       # pinned pages dropped (budget or pool pressure)
        # pages withdrawn from the pool by fault injection (pressure shock):
        # out of _free AND out of usable_pages, so the conservation invariant
        # free + live + pinned == usable holds while capacity is shrunk
        self._seized: list[int] = []

    # -- accounting ----------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1 - len(self._seized)

    @property
    def pages_in_use(self) -> int:
        """Resident pages: refcounted by a slot or pinned in the cache."""
        return self.usable_pages - len(self._free)

    @property
    def pages_pinned(self) -> int:
        return len(self._pinned)

    @property
    def pages_seized(self) -> int:
        return len(self._seized)

    def owned(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def is_pinned(self, page: int) -> bool:
        return page in self._pinned

    def live_refs(self) -> int:
        """Sum of all refcounts == total block-table entries across slots."""
        return int(self._ref.sum())

    def available(self) -> int:
        """Pages acquirable on demand: free or pinned (pinned pages are
        reclaimable cache, evicted under pressure), net of reservations."""
        return len(self._free) + len(self._pinned) - int(self._reserved.sum())

    def can_admit(self, need_pages: int) -> bool:
        """``need_pages`` is the request's *private* page count — full-page
        prefix hits ride on adopted refcounts and are not charged here."""
        return need_pages <= min(self.available(), self.max_pages_per_slot)

    def pinned_among(self, pages) -> int:
        """How many of ``pages`` are currently pinned. Adoption of a pinned
        page consumes reclaimable capacity, so admission must net these out of
        :meth:`available` before charging a request."""
        return sum(1 for p in pages if p in self._pinned)

    def pinned_chain_keys(self) -> list:
        """Token-content keys of the pinned pages — what the persistent prefix
        cache currently holds. Placement telemetry: a fleet router reads this
        (via ``Engine.pinned_chain_keys``) to see which replica already keeps a
        tenant's prompt chains warm."""
        return sorted(self._page_key[p][1] for p in self._pinned)

    # -- durability: pinned-forest export / import ---------------------------
    def export_pinned(self) -> list[dict]:
        """Serialize the indexed prefix forest — pinned cache entries *and*
        live (refcounted) chains — as a parent-first list.

        Each entry carries the page's token key, the index of its parent
        *within the returned list* (-1 = chain root), its class tag and LRU
        stamp, and the physical ``page`` id so the caller can gather the
        page's K/V from the device pool. Live chains are exportable because
        indexed pages are immutable: a registered prompt page is never
        written again (decode writes land in later pages; a CoW fork
        replaces the page in the *owner's* chain, never the shared page), so
        its K/V is as stable as a pinned page's. On import the whole forest
        lands as pinned cache entries — replayed requests adopt them instead
        of re-prefilling, which is what makes a warm restart cheaper than a
        cold one even when the crash hit mid-burst with every chain
        refcounted."""
        out: list[dict] = []
        pos: dict[int, int] = {}
        node_of = {p: self._index[k][0] for p, k in self._page_key.items()}

        def visit(p: int, parent_idx: int) -> None:
            pos[p] = len(out)
            out.append({"tokens": list(self._page_key[p][1]),
                        "parent": parent_idx,
                        "rclass": int(self._page_class[p]),
                        "last_use": int(self._last_use[p]),
                        "page": int(p)})
            for kid in sorted(self._node_kids.get(node_of[p], ())):
                visit(kid, pos[p])

        for root in sorted(p for p in self._page_key
                           if self._page_key[p][0] == 0):
            visit(root, -1)
        return out

    def import_pinned(self, entries: list) -> list[tuple[int, int]]:
        """Rebuild pinned chains from :meth:`export_pinned` output into this
        (typically fresh) allocator: pages come off the free list, are
        indexed, and pinned with their saved class tags and LRU stamps.
        Returns ``(entry_index, new_page)`` pairs so the caller can scatter
        each entry's saved K/V into its new physical page. An entry whose
        parent was not placed (budget/pool exhausted) is skipped with its
        whole subtree — imported chains are always reachable from the root."""
        placed: list[tuple[int, int]] = []
        if not self.share_prefix or self.pin_pages <= 0:
            return placed
        node_of: dict[int, int] = {}
        page_of: dict[int, int] = {}
        for i, e in enumerate(entries):
            if len(self._pinned) >= self.pin_pages or not self._free:
                break
            parent_idx = int(e["parent"])
            if parent_idx >= 0 and parent_idx not in page_of:
                continue                 # orphaned subtree: skip
            parent = 0 if parent_idx < 0 else node_of[parent_idx]
            pt = tuple(int(t) for t in e["tokens"])
            hit = self._index.get((parent, pt))
            if hit is not None:          # already resident (warm import)
                node_of[i], page_of[i] = hit
                continue
            page = self._free.pop()
            node = self._next_node
            self._next_node += 1
            self._index[(parent, pt)] = (node, page)
            self._children.setdefault((parent, pt[0]), set()).add(page)
            self._node_kids.setdefault(parent, set()).add(page)
            self._page_key[page] = (parent, pt)
            self._page_class[page] = self._rc(int(e.get("rclass", 0)))
            self._last_use[page] = int(e.get("last_use", 0))
            self._clock = max(self._clock, int(e.get("last_use", 0)))
            self._pinned.add(page)
            self.pins += 1
            node_of[i], page_of[i] = node, page
            placed.append((i, page))
        self.high_water = max(self.high_water, self.pages_in_use)
        return placed

    def pin_memory_state(self) -> np.ndarray:
        """Host copy of the per-class remembered-prefix-value EMA (the
        immune-memory weights in the eviction score) — snapshot payload."""
        return np.asarray(self.pin_memory.value)

    def set_pin_memory_state(self, values) -> None:
        """Restore the per-class prefix-value EMA saved by
        :meth:`pin_memory_state` (decay stays as configured)."""
        import jax.numpy as jnp
        self.pin_memory = self.pin_memory._replace(
            value=jnp.asarray(values, self.pin_memory.value.dtype))
        self._class_w = np.asarray(self.pin_memory.value)

    # -- prefix index --------------------------------------------------------
    @staticmethod
    def _page_tokens(tokens, i: int, page_size: int) -> tuple:
        return tuple(int(t) for t in tokens[i * page_size:(i + 1) * page_size])

    def match_prefix(self, tokens) -> tuple[list, Optional[tuple]]:
        """Walk ``tokens``'s full pages through the index.

        Returns ``(full_hits, partial)``: ``full_hits`` are the physical pages
        holding the longest indexed chain of full prompt pages; ``partial`` is
        ``(page, r)`` when a child page of that chain additionally matches the
        next ``r`` (< page_size) prompt tokens — adoptable, but the adopter
        must ``cow_fork`` it before writing position ``r`` or beyond. The last
        prompt token is never matched (capped at ``len(tokens) - 1``): the
        caller always recomputes it to produce the first logits. Hits may be
        live (shared with a resident slot) or pinned (cache)."""
        if not self.share_prefix:
            return [], None
        ps = self.page_size
        limit = len(tokens) - 1
        full: list[int] = []
        parent = 0
        while (len(full) + 1) * ps <= limit:
            pt = self._page_tokens(tokens, len(full), ps)
            hit = self._index.get((parent, pt))
            if hit is None:
                break
            parent, pid = hit
            full.append(pid)
        partial = None
        rem = tuple(int(t) for t in tokens[len(full) * ps:limit])
        if rem:
            best, best_r = None, 0
            for pid in self._children.get((parent, rem[0]), ()):
                _, pt = self._page_key[pid]
                r = 0
                for a, b in zip(pt, rem):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best, best_r = pid, r
            if best is not None:
                partial = (best, best_r)
        return full, partial

    def register_prefix(self, slot: int, tokens, rclass: int = 0) -> int:
        """Index ``slot``'s full prompt pages so later admissions can adopt
        them. Call once the pages' K/V is fully resident (prefill complete);
        only pages entirely covered by the prompt are registrable — they are
        never written again (decode writes land at positions >= len(tokens)).
        Pages already indexed (adopted from a donor, or a concurrent duplicate)
        are left alone. Returns the number of pages newly indexed."""
        if not self.share_prefix:
            return 0
        ps = self.page_size
        parent = 0
        n = 0
        self._clock += 1
        for i in range(len(tokens) // ps):
            pt = self._page_tokens(tokens, i, ps)
            pid = self._owned[slot][i]
            hit = self._index.get((parent, pt))
            if hit is not None:
                parent = hit[0]       # adopted (or concurrent-duplicate) page:
                continue              # keep walking the existing chain
            if pid in self._page_key:
                break                 # page busy under another chain: stop
            node = self._next_node
            self._next_node += 1
            self._index[(parent, pt)] = (node, pid)
            self._children.setdefault((parent, pt[0]), set()).add(pid)
            self._node_kids.setdefault(parent, set()).add(pid)
            self._page_key[pid] = (parent, pt)
            self._page_class[pid] = self._rc(rclass)
            self._last_use[pid] = self._clock
            parent = node
            n += 1
        return n

    def _unindex(self, page: int) -> None:
        # a chain node dies with its page; its children are always unindexed
        # first (_drop_chain cascades into pinned kids, and live kids refcount
        # their ancestors), so no dangling parent links survive
        key = self._page_key.pop(page, None)
        if key is not None:
            node, _ = self._index.pop(key)
            self._node_kids.pop(node, None)
            parent = key[0]
            kids = self._node_kids.get(parent)
            if kids is not None:
                kids.discard(page)
                if not kids:
                    del self._node_kids[parent]
            bucket = (key[0], key[1][0])
            kids = self._children.get(bucket)
            if kids is not None:
                kids.discard(page)
                if not kids:
                    del self._children[bucket]

    # -- pinned cache --------------------------------------------------------
    def _rc(self, rclass: int) -> int:
        return min(max(int(rclass), 0), self.num_classes - 1)

    def _note_adoption(self, rclass: int, npages: int) -> None:
        # EMA update for one class, identity for the rest: decay*v + (1-d)*v
        v = self.pin_memory.value
        self.pin_memory = self.pin_memory.update(
            v.at[self._rc(rclass)].set(float(npages)))
        self._class_w = np.asarray(self.pin_memory.value)

    def _score(self, page: int) -> tuple:
        """Eviction ordering: coldest class first, then least recently used."""
        return (float(self._class_w[self._page_class[page]]),
                int(self._last_use[page]), page)

    def _coldest_evictable(self) -> Optional[int]:
        best = None
        for p in self._pinned:
            node = self._index[self._page_key[p]][0]
            if self._node_kids.get(node):
                continue              # not a leaf: eviction would strand kids
            if best is None or self._score(p) < self._score(best):
                best = p
        return best

    def _drop_chain(self, page: int) -> None:
        """Free a refcount-zero page. Pinned descendants are evicted first —
        a live descendant is impossible (every owner of a child page also
        refcounts its ancestors), so the cascade only ever touches cache."""
        key = self._page_key.get(page)
        if key is not None:
            node = self._index[key][0]
            for kid in list(self._node_kids.get(node, ())):
                self._drop_chain(kid)
        if page in self._pinned:
            self._pinned.discard(page)
            self.evictions += 1
        self._unindex(page)
        self._free.append(page)

    def _try_pin(self, page: int) -> bool:
        """Retain a refcount-zero indexed page in the cache. At budget, a
        strictly colder evictable pinned page makes room; otherwise the pin is
        refused (no thrash on ties)."""
        if self.pin_pages <= 0:
            return False
        if len(self._pinned) >= self.pin_pages:
            v = self._coldest_evictable()
            if v is None or not self._score(v) < self._score(page):
                return False
            self._drop_chain(v)
        self._pinned.add(page)
        self.pins += 1
        return True

    def _take_page(self) -> int:
        """Pop a free page, evicting the coldest pinned leaf if none is free."""
        if not self._free:
            v = self._coldest_evictable()
            if v is None:
                raise OutOfPages(
                    f"no free or evictable page ({self.pages_in_use}/"
                    f"{self.usable_pages} in use, {len(self._pinned)} pinned)")
            self._drop_chain(v)
        return self._free.pop()

    # -- fault injection ------------------------------------------------------
    def seize(self, npages: int) -> int:
        """Withdraw up to ``npages`` from the pool (a pressure shock: the
        host reclaiming memory, a co-tenant ballooning, an HBM page going
        bad). Free pages go first, then the coldest pinned cache leaves; live
        (refcounted) pages are never seized. Seized pages leave
        ``usable_pages`` entirely, so the conservation invariant
        ``free + live + pinned == usable`` holds while capacity is shrunk.
        Returns how many pages were actually taken — under full live
        occupancy the shock can land short."""
        taken = 0
        while taken < npages:
            if not self._free:
                v = self._coldest_evictable()
                if v is None:
                    break
                self._drop_chain(v)
            self._seized.append(self._free.pop())
            taken += 1
        return taken

    def restore(self, npages: Optional[int] = None) -> int:
        """Return seized pages (all, or the ``npages`` most recently seized)
        to the free pool, growing ``usable_pages`` back. Returns the count
        restored."""
        n = len(self._seized) if npages is None else min(npages,
                                                         len(self._seized))
        for _ in range(n):
            self._free.append(self._seized.pop())
        return n

    # -- lifecycle -----------------------------------------------------------
    def reserve(self, slot: int, need_pages: int) -> None:
        """Promise ``need_pages`` *private* pages to ``slot`` (its worst case
        net of full-page prefix hits); call at admission, after ``adopt`` so
        revived pinned pages are already netted out of :meth:`available`."""
        if self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds a reservation")
        if not self.can_admit(need_pages):
            raise RuntimeError(f"reserve({slot}, {need_pages}) exceeds "
                               f"available {self.available()}")
        self._reserved[slot] = need_pages

    def adopt(self, slot: int, pages, rclass: int = 0) -> None:
        """Append already-resident ``pages`` to ``slot``'s chain with
        refcount++ — the prefix-sharing admission path. Hits may be live
        (shared with a resident slot) or pinned (revived from the cache);
        free pages are not adoptable. Tags the pages with the adopter's class
        and feeds the per-class prefix-value EMA."""
        rc = self._rc(rclass)
        self._clock += 1
        for p in pages:
            if p == NULL_PAGE:
                raise RuntimeError(f"adopt({slot}, {p}): null page")
            if self._ref[p] <= 0:
                if p not in self._pinned:
                    raise RuntimeError(f"adopt({slot}, {p}): page is not live")
                self._pinned.discard(p)
                self.pinned_hits += 1
            self._ref[p] += 1
            self._owned[slot].append(p)
            self._page_class[p] = rc
            self._last_use[p] = self._clock
        if pages:
            self._note_adoption(rc, len(pages))

    def ensure(self, slot: int, npages: int) -> None:
        """Grow ``slot`` to at least ``npages`` logical pages (adopted pages
        count toward the total). Called before a prefill chunk lands or a
        decode write crosses a page boundary. Under reservation discipline the
        growth must be covered by the slot's reservation; under preemption it
        draws freely and raises :class:`OutOfPages` on exhaustion."""
        if npages > self.max_pages_per_slot:
            raise RuntimeError(f"slot {slot}: {npages} pages exceeds "
                               f"max_pages_per_slot {self.max_pages_per_slot}")
        while len(self._owned[slot]) < npages:
            if self.require_reservation and self._reserved[slot] <= 0:
                raise RuntimeError(f"slot {slot} grew past its reservation")
            page = self._take_page()
            self._ref[page] = 1
            self._owned[slot].append(page)
            if self._reserved[slot] > 0:
                self._reserved[slot] -= 1
            self.high_water = max(self.high_water, self.pages_in_use)

    def cow_fork(self, slot: int, logical_idx: int) -> tuple[int, int]:
        """Copy-on-write: replace the shared page at ``slot``'s chain position
        ``logical_idx`` with a fresh private page and drop one ref on the
        shared page. Returns ``(src, dst)``; the caller must copy the shared
        prefix entries ``src -> dst`` on device *before* dispatching any write
        that could recycle ``src``. The fork target comes off the free list
        (or an evicted cache page), so it can never alias a still-shared
        page. A source whose refcount hits zero is pinned if possible."""
        src = self._owned[slot][logical_idx]
        if src == NULL_PAGE or self._ref[src] <= 0:
            raise RuntimeError(f"cow_fork({slot}, {logical_idx}): no live page")
        if self.require_reservation and self._reserved[slot] <= 0:
            raise RuntimeError(f"slot {slot}: fork exceeds its reservation")
        dst = self._take_page()
        if self._reserved[slot] > 0:
            self._reserved[slot] -= 1
        self._ref[dst] = 1
        self._ref[src] -= 1
        if self._ref[src] == 0:
            if not (src in self._page_key and self._try_pin(src)):
                self._drop_chain(src)
        self._owned[slot][logical_idx] = dst
        self.cow_forks += 1
        self.high_water = max(self.high_water, self.pages_in_use)
        return src, dst

    def release(self, slot: int) -> None:
        """Retire ``slot``: drop one ref on each of its pages and return any
        unused reservation. Pages still shared by other slots stay resident
        and indexed; refcount-zero *indexed* pages are pinned into the cache
        while the budget holds (shallowest first, so a retained chain is
        always reachable from the root), the rest freed deepest-first. No
        zeroing: stale page contents are only ever read masked."""
        zeros: list[int] = []
        for p in self._owned[slot]:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                zeros.append(p)
        self._owned[slot] = []
        self._reserved[slot] = 0
        # zeros appear in logical = shallow-to-deep chain order (indexed
        # prompt pages form a contiguous chain prefix of the slot's pages).
        # Once one indexed page fails to pin, everything deeper would dangle,
        # so it frees instead — children before parents.
        broken = False
        leftover: list[int] = []
        for p in zeros:
            if not broken and p in self._page_key and self._try_pin(p):
                continue
            if p in self._page_key:
                broken = True
            leftover.append(p)
        for p in reversed(leftover):
            self._drop_chain(p)

    # -- device view ---------------------------------------------------------
    def table(self) -> np.ndarray:
        """(num_slots, max_pages_per_slot) int32 block table; unmapped entries
        point at the null page. Shared pages appear in several rows at once.
        Pinned pages appear in no row — they are cache, not state."""
        t = np.full((self.num_slots, self.max_pages_per_slot), NULL_PAGE,
                    np.int32)
        for slot, pages in enumerate(self._owned):
            t[slot, :len(pages)] = pages
        return t
