"""Host-side block-table allocator for the paged KV cache (vLLM-style), with
**refcounted prefix sharing** and copy-on-write forking.

The device holds one physical page pool per attention layer, shaped
``(num_pages, page_size, kv_heads, head_dim)``; this module owns the *mapping*:
which physical pages belong to which decode slot, in logical order. The device
side never sees the free list — only the dense ``(num_slots, max_pages_per_slot)``
block table produced by :meth:`PageAllocator.table`.

Ownership is **refcounted**: a physical page may appear in several slots' block
tables at once when those slots share a prompt prefix. A host-side **prefix
index** maps chains of *full pages of prompt token ids* to the physical page
already holding their K/V: admission walks the new prompt's pages through the
index and adopts every hit with ``refcount++`` instead of reserving and
re-prefilling it (``adopt``). K/V at a position is a pure function of the token
prefix for text-only stacks, so adopted pages are bitwise what the request's
own prefill would have written — the caller gates sharing to such configs. The
index is keyed by (interned chain-prefix id, full page token tuple) — content
equality, not hashing — so a chain hit can never be a collision.

A shared page is immutable to its adopters. When a slot must write into one —
the unshared tail of its prompt starts mid-page after a partial-page hit — it
**copy-on-write forks** it first (``cow_fork``): a fresh page replaces the
shared one in this slot's chain, the shared page's refcount drops, and the
caller copies the shared prefix entries on device before writing. A fork target
always comes off the free list, so a fork can never alias a still-shared page.

Layout invariants (the hypothesis suite in ``tests/test_paging.py`` churns these):

  * page 0 is the **null page**: never allocated, permanently parked. Unmapped
    block-table entries point at it, and the decode step routes the writes of
    inactive slots there, so it doubles as the trash page. Reads of it are
    always masked, so its contents are irrelevant as long as they stay finite.
  * ``sum(refcounts) == total live block-table entries`` — every owner of a
    page is counted, and nothing else is;
  * no page is ever on the free list while its refcount is > 0, and a page
    whose refcount hits zero is freed immediately (free-on-zero) and dropped
    from the prefix index — index entries only ever point at live pages;
  * ``free + distinct live pages == num_pages - 1`` (conservation, null page
    excluded — a shared page counts once, which is the memory win);
  * ``available()`` never goes negative: admission *reserves* a request's
    private (unshared) page count up front (``reserve``), then pages are
    physically appended lazily (``ensure``) as prefill chunks land and decode
    crosses page boundaries — so a slot can never deadlock mid-decode waiting
    for a page another slot might never release. Adopted pages are never
    charged against the reservation; a CoW fork draws one page from it.

Reservation is per-request worst case over its *private* pages
(``ceil((prompt + decode budget)/page) - shared full-page hits``) — with a hot
shared prefix this is far below the unshared worst case, which is the point:
prefix-heavy traffic admits O(unique tokens) of KV memory, not O(total).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``tokens`` cache positions."""
    return -(-tokens // page_size)


class PageAllocator:
    """Refcounted free-list page allocator with per-slot reservations and a
    prefix-sharing index.

    ``num_pages`` counts the null page, so ``num_pages - 1`` pages are usable.
    ``share_prefix=False`` disables the index (every page single-owner, the
    pre-sharing behavior) without changing any other semantics.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_pages_per_slot: int, share_prefix: bool = True):
        if num_pages < 2:
            raise ValueError("need at least one usable page beyond the null page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_pages_per_slot = max_pages_per_slot
        self.share_prefix = share_prefix
        # pop() order is ascending page id — cosmetic, but makes traces readable
        self._free = list(range(num_pages - 1, NULL_PAGE, -1))
        self._owned: list[list[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros(num_slots, np.int64)
        self._ref = np.zeros(num_pages, np.int64)
        # prefix index: a page holding the i-th full page of a prompt is keyed
        # by (chain node id of pages 0..i-1, its own page_size token ids).
        # Node ids *intern* chain prefixes — one id per distinct content path,
        # assigned at registration — so a hit is still full-content equality
        # (never a hash collision), but each dict access hashes O(page_size)
        # instead of rehashing the whole nested prefix: index walks stay
        # linear in the prompt length. Node id 0 is the empty chain.
        self._index: dict[tuple, tuple] = {}    # (parent id, pt) -> (node, page)
        # partial-match candidates, bucketed by (parent node, first token) so
        # a busy divergence point (e.g. many distinct prompts under the root)
        # never costs a linear scan over all its children
        self._children: dict[tuple, set] = {}
        self._page_key: dict[int, tuple] = {}   # page id -> its index key
        self._next_node = 1
        self.high_water = 0
        self.cow_forks = 0

    # -- accounting ----------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def pages_in_use(self) -> int:
        return self.usable_pages - len(self._free)

    def owned(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def live_refs(self) -> int:
        """Sum of all refcounts == total block-table entries across slots."""
        return int(self._ref.sum())

    def available(self) -> int:
        """Pages neither allocated nor promised to a live slot."""
        return len(self._free) - int(self._reserved.sum())

    def can_admit(self, need_pages: int) -> bool:
        """``need_pages`` is the request's *private* page count — full-page
        prefix hits ride on adopted refcounts and are not charged here."""
        return need_pages <= min(self.available(), self.max_pages_per_slot)

    # -- prefix index --------------------------------------------------------
    @staticmethod
    def _page_tokens(tokens, i: int, page_size: int) -> tuple:
        return tuple(int(t) for t in tokens[i * page_size:(i + 1) * page_size])

    def match_prefix(self, tokens) -> tuple[list, Optional[tuple]]:
        """Walk ``tokens``'s full pages through the index.

        Returns ``(full_hits, partial)``: ``full_hits`` are the physical pages
        holding the longest indexed chain of full prompt pages; ``partial`` is
        ``(page, r)`` when a child page of that chain additionally matches the
        next ``r`` (< page_size) prompt tokens — adoptable, but the adopter
        must ``cow_fork`` it before writing position ``r`` or beyond. The last
        prompt token is never matched (capped at ``len(tokens) - 1``): the
        caller always recomputes it to produce the first logits."""
        if not self.share_prefix:
            return [], None
        ps = self.page_size
        limit = len(tokens) - 1
        full: list[int] = []
        parent = 0
        while (len(full) + 1) * ps <= limit:
            pt = self._page_tokens(tokens, len(full), ps)
            hit = self._index.get((parent, pt))
            if hit is None:
                break
            parent, pid = hit
            full.append(pid)
        partial = None
        rem = tuple(int(t) for t in tokens[len(full) * ps:limit])
        if rem:
            best, best_r = None, 0
            for pid in self._children.get((parent, rem[0]), ()):
                _, pt = self._page_key[pid]
                r = 0
                for a, b in zip(pt, rem):
                    if a != b:
                        break
                    r += 1
                if r > best_r:
                    best, best_r = pid, r
            if best is not None:
                partial = (best, best_r)
        return full, partial

    def register_prefix(self, slot: int, tokens) -> int:
        """Index ``slot``'s full prompt pages so later admissions can adopt
        them. Call once the pages' K/V is fully resident (prefill complete);
        only pages entirely covered by the prompt are registrable — they are
        never written again (decode writes land at positions >= len(tokens)).
        Pages already indexed (adopted from a donor, or a concurrent duplicate)
        are left alone. Returns the number of pages newly indexed."""
        if not self.share_prefix:
            return 0
        ps = self.page_size
        parent = 0
        n = 0
        for i in range(len(tokens) // ps):
            pt = self._page_tokens(tokens, i, ps)
            pid = self._owned[slot][i]
            hit = self._index.get((parent, pt))
            if hit is not None:
                parent = hit[0]       # adopted (or concurrent-duplicate) page:
                continue              # keep walking the existing chain
            if pid in self._page_key:
                break                 # page busy under another chain: stop
            node = self._next_node
            self._next_node += 1
            self._index[(parent, pt)] = (node, pid)
            self._children.setdefault((parent, pt[0]), set()).add(pid)
            self._page_key[pid] = (parent, pt)
            parent = node
            n += 1
        return n

    def _unindex(self, page: int) -> None:
        # a chain node dies with its page; its children are always unindexed
        # first (every owner of a child page also refcounts its ancestors, and
        # release frees deepest-first), so no dangling parent links survive
        key = self._page_key.pop(page, None)
        if key is not None:
            self._index.pop(key)
            bucket = (key[0], key[1][0])
            kids = self._children.get(bucket)
            if kids is not None:
                kids.discard(page)
                if not kids:
                    del self._children[bucket]

    # -- lifecycle -----------------------------------------------------------
    def reserve(self, slot: int, need_pages: int) -> None:
        """Promise ``need_pages`` *private* pages to ``slot`` (its worst case
        net of full-page prefix hits); call at admission, before ``adopt``."""
        if self._owned[slot] or self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds pages/reservation")
        if not self.can_admit(need_pages):
            raise RuntimeError(f"reserve({slot}, {need_pages}) exceeds "
                               f"available {self.available()}")
        self._reserved[slot] = need_pages

    def adopt(self, slot: int, pages) -> None:
        """Append already-resident ``pages`` to ``slot``'s chain with
        refcount++ — the prefix-sharing admission path. Free pages are not
        adoptable (free-on-zero means a page with owners is never free)."""
        for p in pages:
            if p == NULL_PAGE or self._ref[p] <= 0:
                raise RuntimeError(f"adopt({slot}, {p}): page is not live")
            self._ref[p] += 1
            self._owned[slot].append(p)

    def ensure(self, slot: int, npages: int) -> None:
        """Grow ``slot`` to at least ``npages`` logical pages (within its
        reservation; adopted pages count toward the total). Called before a
        prefill chunk lands or a decode write crosses a page boundary."""
        if npages > self.max_pages_per_slot:
            raise RuntimeError(f"slot {slot}: {npages} pages exceeds "
                               f"max_pages_per_slot {self.max_pages_per_slot}")
        while len(self._owned[slot]) < npages:
            if self._reserved[slot] <= 0:
                raise RuntimeError(f"slot {slot} grew past its reservation")
            page = self._free.pop()
            self._ref[page] = 1
            self._owned[slot].append(page)
            self._reserved[slot] -= 1
            self.high_water = max(self.high_water, self.pages_in_use)

    def cow_fork(self, slot: int, logical_idx: int) -> tuple[int, int]:
        """Copy-on-write: replace the shared page at ``slot``'s chain position
        ``logical_idx`` with a fresh private page (drawn from the slot's
        reservation) and drop one ref on the shared page. Returns
        ``(src, dst)``; the caller must copy the shared prefix entries
        ``src -> dst`` on device *before* dispatching any write that could
        recycle ``src``. The fork target comes off the free list, so it can
        never alias a still-shared page."""
        src = self._owned[slot][logical_idx]
        if src == NULL_PAGE or self._ref[src] <= 0:
            raise RuntimeError(f"cow_fork({slot}, {logical_idx}): no live page")
        if self._reserved[slot] <= 0:
            raise RuntimeError(f"slot {slot}: fork exceeds its reservation")
        dst = self._free.pop()
        self._reserved[slot] -= 1
        self._ref[dst] = 1
        self._ref[src] -= 1
        if self._ref[src] == 0:
            self._unindex(src)
            self._free.append(src)
        self._owned[slot][logical_idx] = dst
        self.cow_forks += 1
        self.high_water = max(self.high_water, self.pages_in_use)
        return src, dst

    def release(self, slot: int) -> None:
        """Retire ``slot``: drop one ref on each of its pages (free-on-zero —
        pages still shared by other slots stay resident and indexed) and return
        any unused reservation. No zeroing: stale page contents are only ever
        read masked."""
        for p in reversed(self._owned[slot]):
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._unindex(p)
                self._free.append(p)
        self._owned[slot] = []
        self._reserved[slot] = 0

    # -- device view ---------------------------------------------------------
    def table(self) -> np.ndarray:
        """(num_slots, max_pages_per_slot) int32 block table; unmapped entries
        point at the null page. Shared pages appear in several rows at once."""
        t = np.full((self.num_slots, self.max_pages_per_slot), NULL_PAGE,
                    np.int32)
        for slot, pages in enumerate(self._owned):
            t[slot, :len(pages)] = pages
        return t
