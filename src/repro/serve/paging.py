"""Host-side block-table allocator for the paged KV cache (vLLM-style).

The device holds one physical page pool per attention layer, shaped
``(num_pages, page_size, kv_heads, head_dim)``; this module owns the *mapping*:
which physical pages belong to which decode slot, in logical order. The device
side never sees the free list — only the dense ``(num_slots, max_pages_per_slot)``
block table produced by :meth:`PageAllocator.table`.

Layout invariants (the hypothesis suite in ``tests/test_paging.py`` churns these):

  * page 0 is the **null page**: never allocated, permanently parked. Unmapped
    block-table entries point at it, and the decode step routes the writes of
    inactive slots there, so it doubles as the trash page. Reads of it are
    always masked (its logical positions are beyond every slot's ``pos``), so
    its contents are irrelevant as long as they stay finite.
  * no physical page is ever owned by two live slots;
  * ``free + sum(owned) == num_pages - 1`` (conservation, null page excluded);
  * ``available()`` never goes negative: admission *reserves* a request's
    worst-case page count up front (``reserve``), then pages are physically
    appended lazily (``ensure``) as prefill chunks land and decode crosses page
    boundaries — so a slot can never deadlock mid-decode waiting for a page
    another slot might never release.

Reservation is per-request worst case (``ceil((prompt + decode budget)/page)``)
— far smaller than the fixed-row engine's ``max_cache`` row, which is the whole
point: mixed-length requests admit without the worst-case reservation.
"""
from __future__ import annotations

import numpy as np

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Physical pages needed to hold ``tokens`` cache positions."""
    return -(-tokens // page_size)


class PageAllocator:
    """Free-list page allocator with per-slot reservations.

    ``num_pages`` counts the null page, so ``num_pages - 1`` pages are usable.
    """

    def __init__(self, num_pages: int, page_size: int, num_slots: int,
                 max_pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need at least one usable page beyond the null page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.num_slots = num_slots
        self.max_pages_per_slot = max_pages_per_slot
        # pop() order is ascending page id — cosmetic, but makes traces readable
        self._free = list(range(num_pages - 1, NULL_PAGE, -1))
        self._owned: list[list[int]] = [[] for _ in range(num_slots)]
        self._reserved = np.zeros(num_slots, np.int64)
        self.high_water = 0

    # -- accounting ----------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        return self.num_pages - 1

    @property
    def pages_in_use(self) -> int:
        return self.usable_pages - len(self._free)

    def owned(self, slot: int) -> list[int]:
        return list(self._owned[slot])

    def available(self) -> int:
        """Pages neither allocated nor promised to a live slot."""
        return len(self._free) - int(self._reserved.sum())

    def can_admit(self, need_pages: int) -> bool:
        return need_pages <= min(self.available(), self.max_pages_per_slot)

    # -- lifecycle -----------------------------------------------------------
    def reserve(self, slot: int, need_pages: int) -> None:
        """Promise ``need_pages`` to ``slot`` (its worst case); call at admission."""
        if self._owned[slot] or self._reserved[slot]:
            raise RuntimeError(f"slot {slot} already holds pages/reservation")
        if not self.can_admit(need_pages):
            raise RuntimeError(f"reserve({slot}, {need_pages}) exceeds "
                               f"available {self.available()}")
        self._reserved[slot] = need_pages

    def ensure(self, slot: int, npages: int) -> None:
        """Grow ``slot`` to at least ``npages`` physical pages (within its
        reservation). Called before a prefill chunk lands or a decode write
        crosses a page boundary."""
        if npages > self.max_pages_per_slot:
            raise RuntimeError(f"slot {slot}: {npages} pages exceeds "
                               f"max_pages_per_slot {self.max_pages_per_slot}")
        while len(self._owned[slot]) < npages:
            if self._reserved[slot] <= 0:
                raise RuntimeError(f"slot {slot} grew past its reservation")
            self._owned[slot].append(self._free.pop())
            self._reserved[slot] -= 1
            self.high_water = max(self.high_water, self.pages_in_use)

    def release(self, slot: int) -> None:
        """Retire ``slot``: return its pages (and any unused reservation — an
        early EOS leaves some) to the pool. No zeroing: stale page contents are
        only ever read masked."""
        self._free.extend(reversed(self._owned[slot]))
        self._owned[slot] = []
        self._reserved[slot] = 0

    # -- device view ---------------------------------------------------------
    def table(self) -> np.ndarray:
        """(num_slots, max_pages_per_slot) int32 block table; unmapped entries
        point at the null page."""
        t = np.full((self.num_slots, self.max_pages_per_slot), NULL_PAGE,
                    np.int32)
        for slot, pages in enumerate(self._owned):
            t[slot, :len(pages)] = pages
        return t
