"""Deterministic fault injection for the multi-replica serving fleet.

Fault tolerance is a core evaluation axis for dynamic load balancers (Mandal
& Pal, arXiv:1109.1650), and the immune metaphor's headline property is
resilience, not speed: regulation, tolerance, and memory exist so the system
keeps functioning while components die or misbehave. This module makes that
claim measurable: a :class:`FaultPlan` is a *seeded, tick-exact script* of
replica failures; a :class:`FaultInjector` applies it to a
``serve.router.Router`` fleet while the router's health machine
(healthy -> suspect -> dead from missed step deadlines) detects and recovers.
Everything is host-side and tick-driven, so a given ``(trace, plan, policy)``
triple replays identically — which is what lets the benchmark assert that
every *surviving* request's tokens are bitwise identical to the fault-free
run.

Fault kinds (``FaultEvent.kind``):

  * ``"crash"``    — the replica stops stepping, permanently, with no
    goodbye: its queue and resident slots are stranded until the router's
    missed-deadline health machine declares it dead and evacuates them onto
    survivors (fail-stop, detected not announced).
  * ``"slow"``     — for ``duration`` ticks the replica steps only once
    every ``factor`` fleet ticks (a straggler: thermal throttling, a noisy
    neighbour, a background compaction).
  * ``"stall"``    — for ``duration`` ticks the replica does not step at all,
    then resumes on its own (a GC pause / network partition that heals). If
    the stall outlives the router's ``dead_after`` deadline the replica is
    declared dead and *fenced* — real systems cannot un-declare a death, so
    a late-healing stall rejoins only via an explicit ``rejoin`` event.
  * ``"pressure"`` — ``pages`` KV pages are seized from the replica's pool
    for ``duration`` ticks (host memory reclaim / a co-tenant ballooning);
    the allocator's conservation invariant holds throughout
    (``PageAllocator.seize`` / ``restore``).
  * ``"rejoin"``   — a crashed (or fenced) replica returns as a *fresh*
    process: a new ``Engine`` with a cold pinned prefix cache and blank
    immune state, built by the injector's ``engine_factory``. The router
    re-admits it at full health; prefix-affinity traffic rewarms its cache.

Beyond the per-replica kinds, two *fleet-wide* kinds script a full power
loss (``FLEET_FAULT_KINDS``):

  * ``"poweroff"`` — fail-stop of the ENTIRE fleet, router included: every
    replica, every in-flight request, every byte of device state is gone at
    once. The injector signals it by raising :class:`PowerLoss`; nothing
    in-process survives to "handle" it — recovery happens out-of-band from
    the write-ahead journal + warm snapshot (``serve.durability.run_durable``
    catches the exception, truncates the journal to its last fsync'd byte,
    and rebuilds a fresh fleet via ``Router.recover``).
  * ``"restart"`` — the tick at which the rebuilt fleet resumes serving.
    Optional (a plan may power off forever); when present it must follow a
    ``poweroff``, validated exactly like crash/rejoin pairing. On the
    post-recovery injector the event is a no-op marker: the recovery it
    names has already happened by the time the tick is reached.

Fleet-wide events take no ``:rN`` field (``replica`` is the ``-1``
sentinel). Window state for per-replica faults (slow/stall/pressure) is
in-RAM and dies with the process: a window straddling a poweroff does not
resume after recovery — real machines forget their throttling too.

Plan spec grammar (the ``launch/serve --faults`` format), whitespace- or
comma-separated events::

    kind@tick[+duration]:rREPLICA[:xFACTOR][:pPAGES]
    poweroff@tick  restart@tick

    crash@40:r1  rejoin@90:r1  slow@10+30:r0:x3  stall@15+4:r2
    pressure@20+10:r0:p4  poweroff@30 restart@34
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

FAULT_KINDS = ("crash", "slow", "stall", "pressure", "rejoin")
FLEET_FAULT_KINDS = ("poweroff", "restart")
_ALL_KINDS = FAULT_KINDS + FLEET_FAULT_KINDS


class PowerLoss(Exception):
    """Raised by :meth:`FaultInjector.begin_tick` when a ``poweroff`` event
    fires: the whole fleet fail-stops at ``tick``. ``restart_tick`` is the
    plan's next scheduled ``restart`` (None = off forever). In-process
    state must be treated as lost; only the journal's fsync'd prefix and
    the last completed snapshot survive."""

    def __init__(self, tick: int, restart_tick: Optional[int] = None):
        super().__init__(f"fleet power loss at tick {tick}"
                         + (f", restart at {restart_tick}"
                            if restart_tick is not None else ""))
        self.tick = tick
        self.restart_tick = restart_tick


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault: ``kind`` fires at fleet tick ``tick`` on
    ``replica``. ``duration`` bounds the slow/stall/pressure window;
    ``factor`` is the slow replica's step divisor; ``pages`` the pressure
    shock's seized page count."""

    tick: int
    kind: str
    replica: int = -1          # -1 = fleet-wide (poweroff / restart)
    duration: int = 0
    factor: int = 2
    pages: int = 0

    def __post_init__(self):
        if self.kind not in _ALL_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_ALL_KINDS}")
        if self.kind in FLEET_FAULT_KINDS:
            if self.tick < 0:
                raise ValueError(f"fault tick must be >= 0: {self}")
            if self.replica != -1:
                raise ValueError(f"{self.kind} is fleet-wide and takes no "
                                 f"replica: {self}")
            return
        if self.tick < 0 or self.replica < 0:
            raise ValueError(f"fault tick/replica must be >= 0: {self}")
        if self.kind in ("slow", "stall", "pressure") and self.duration < 1:
            raise ValueError(f"{self.kind} fault needs duration >= 1: {self}")
        if self.kind == "slow" and self.factor < 2:
            raise ValueError(f"slow fault needs factor >= 2: {self}")
        if self.kind == "pressure" and self.pages < 1:
            raise ValueError(f"pressure fault needs pages >= 1: {self}")


class FaultPlan:
    """An ordered, validated script of :class:`FaultEvent`. Plans are data:
    build programmatically, or parse the compact CLI spec with
    :meth:`parse`."""

    def __init__(self, events: List[FaultEvent]):
        self.events = sorted(events, key=lambda e: (e.tick, e.replica,
                                                    _ALL_KINDS.index(e.kind)))
        down: set = set()
        fleet_down = False
        for e in self.events:
            if e.kind == "crash":
                if e.replica in down:
                    raise ValueError(f"replica r{e.replica} crashed twice "
                                     f"without a rejoin (tick {e.tick})")
                down.add(e.replica)
            elif e.kind == "rejoin":
                if e.replica not in down:
                    raise ValueError(f"rejoin of r{e.replica} at tick "
                                     f"{e.tick} without a prior crash")
                down.discard(e.replica)
            elif e.kind == "poweroff":
                if fleet_down:
                    raise ValueError(f"fleet powered off twice without a "
                                     f"restart (tick {e.tick})")
                fleet_down = True
            elif e.kind == "restart":
                if not fleet_down:
                    raise ValueError(f"restart at tick {e.tick} without a "
                                     f"prior poweroff")
                fleet_down = False

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)

    def events_at(self, tick: int) -> List[FaultEvent]:
        return [e for e in self.events if e.tick == tick]

    def max_replica(self) -> int:
        return max((e.replica for e in self.events), default=-1)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the CLI grammar (module docstring): e.g.
        ``"crash@40:r1 rejoin@90:r1 slow@10+30:r0:x3"``."""
        events = []
        for tok in spec.replace(",", " ").split():
            try:
                head, _, rest = tok.partition(":")
                kind, _, when = head.partition("@")
                tick, _, dur = when.partition("+")
                if kind in FLEET_FAULT_KINDS:
                    if rest or dur:
                        raise ValueError(f"{kind} is fleet-wide: bare "
                                         f"{kind}@tick only")
                    events.append(FaultEvent(tick=int(tick), kind=kind))
                    continue
                fields = rest.split(":")
                if not fields or not fields[0].startswith("r"):
                    raise ValueError("missing :rN replica field")
                kw = dict(tick=int(tick), kind=kind,
                          replica=int(fields[0][1:]))
                if dur:
                    kw["duration"] = int(dur)
                for f in fields[1:]:
                    if f.startswith("x"):
                        kw["factor"] = int(f[1:])
                    elif f.startswith("p"):
                        kw["pages"] = int(f[1:])
                    else:
                        raise ValueError(f"unknown modifier {f!r}")
                events.append(FaultEvent(**kw))
            except (ValueError, IndexError) as err:
                raise ValueError(f"bad fault spec token {tok!r}: {err}") \
                    from None
        return cls(events)

    @classmethod
    def crash_of_one(cls, replica: int, at: int,
                     rejoin_at: Optional[int] = None) -> "FaultPlan":
        """The benchmark's canonical plan: one replica crashes at ``at``,
        optionally rejoining (cold) at ``rejoin_at``."""
        events = [FaultEvent(tick=at, kind="crash", replica=replica)]
        if rejoin_at is not None:
            events.append(FaultEvent(tick=rejoin_at, kind="rejoin",
                                     replica=replica))
        return cls(events)

    @classmethod
    def poweroff_at(cls, at: int,
                    restart_at: Optional[int] = None) -> "FaultPlan":
        """The durability benchmark's canonical plan: the whole fleet
        fail-stops at ``at``, optionally resuming (post-recovery) at
        ``restart_at``."""
        events = [FaultEvent(tick=at, kind="poweroff")]
        if restart_at is not None:
            events.append(FaultEvent(tick=restart_at, kind="restart"))
        return cls(events)

    def restart_after(self, tick: int) -> Optional[int]:
        """Tick of the first ``restart`` event strictly after ``tick``
        (None if the plan stays dark)."""
        for e in self.events:
            if e.kind == "restart" and e.tick > tick:
                return e.tick
        return None


class FaultInjector:
    """Applies a :class:`FaultPlan` to a router fleet, one call per fleet
    tick (``Router.step`` drives it). The injector *causes* faults — it
    never tells the router about them: crash detection is the router's own
    missed-deadline health machine, exactly as it would be across a real IPC
    boundary. ``engine_factory() -> Engine`` builds the fresh replica a
    ``rejoin`` event swaps in (required only if the plan contains one)."""

    def __init__(self, plan: FaultPlan,
                 engine_factory: Optional[Callable] = None):
        self.plan = plan
        self.engine_factory = engine_factory
        if engine_factory is None and any(e.kind == "rejoin" for e in plan):
            raise ValueError("plan contains a rejoin event: the injector "
                             "needs an engine_factory to build the fresh "
                             "replica")
        self.crashed: set = set()
        self._slow: dict = {}        # replica -> (start, until, factor)
        self._stalled: dict = {}     # replica -> until
        self._pressured: dict = {}   # replica -> (until, alloc, npages)
        self.crashes = 0
        self.rejoins = 0
        self.stalls = 0
        self.slowdowns = 0
        self.pressure_shocks = 0
        self.pages_seized = 0
        self.poweroffs = 0

    def begin_tick(self, router) -> None:
        """Fire this tick's events and expire elapsed windows. Called by
        ``Router.step`` before placement, so a tick-T fault is visible to
        tick-T scheduling decisions exactly like a real failure would be."""
        t = router.tick
        for i, (until, alloc, n) in list(self._pressured.items()):
            if t >= until:
                alloc.restore(n)
                del self._pressured[i]
        for i, until in list(self._stalled.items()):
            if t >= until:
                del self._stalled[i]
        for i, (_, until, _) in list(self._slow.items()):
            if t >= until:
                del self._slow[i]
        for e in self.plan.events_at(t):
            if e.kind == "poweroff":
                # the lights go out mid-tick: no cleanup, no goodbye — the
                # caller's process state is dead and recovery is out-of-band
                # (journal + snapshot via serve.durability)
                self.poweroffs += 1
                raise PowerLoss(t, self.plan.restart_after(t))
            if e.kind == "restart":
                continue       # recovery already happened before this tick
            if e.replica >= len(router.engines):
                raise ValueError(f"fault targets replica r{e.replica} but "
                                 f"the fleet has {len(router.engines)}")
            if e.kind == "crash":
                self.crashed.add(e.replica)
                self.crashes += 1
            elif e.kind == "rejoin":
                self.crashed.discard(e.replica)
                router.rejoin(e.replica, self.engine_factory())
                self.rejoins += 1
            elif e.kind == "slow":
                self._slow[e.replica] = (t, t + e.duration, e.factor)
                self.slowdowns += 1
            elif e.kind == "stall":
                self._stalled[e.replica] = t + e.duration
                self.stalls += 1
            elif e.kind == "pressure":
                alloc = router.engines[e.replica].alloc
                taken = alloc.seize(e.pages)
                self._pressured[e.replica] = (t + e.duration, alloc, taken)
                self.pressure_shocks += 1
                self.pages_seized += taken

    def can_step(self, i: int, tick: int) -> bool:
        """May replica ``i`` advance this fleet tick? False while crashed or
        stalled; a slow replica steps once every ``factor`` ticks."""
        if i in self.crashed or i in self._stalled:
            return False
        if i in self._slow:
            start, _, factor = self._slow[i]
            return (tick - start) % factor == 0
        return True

    def stats(self) -> dict:
        return {
            "fault_events": len(self.plan),
            "crashes": self.crashes,
            "rejoins": self.rejoins,
            "stalls": self.stalls,
            "slowdowns": self.slowdowns,
            "pressure_shocks": self.pressure_shocks,
            "pages_seized": self.pages_seized,
            "poweroffs": self.poweroffs,
        }
