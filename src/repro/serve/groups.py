"""Slot groups: one request owning n engine lanes that share prompt pages.

A ``ServeRequest`` whose ``SamplingParams.n`` / ``best_of`` exceeds 1 is a
*parent*: it never occupies a slot itself. :func:`expand` turns it into
``group_size`` member requests — identical prompt (the same host array, so the
prefix index sees byte-identical pages and members adopt the lane-0 prefix
registration refcount-only, charging the prompt's pages once), per-lane seeds
(lane 0 keeps the parent seed; lane ``i`` folds ``seed + i`` so lanes draw
distinct sample streams), and ``n=1`` member params so members schedule like
ordinary requests everywhere below the group layer.

Joint lifecycle semantics live here as pure functions over member state:

  * admission  — the engine admits lane 0 first (it prefills and registers the
    shared prefix), then the sibling lanes, which adopt those pages; a group
    is never half-scheduled for long (siblings are next in FIFO order).
  * preemption — evicting one member cascades to its resident siblings
    (``Engine._preempt``), so a group's lanes move through the queue together
    and the shared prefix refcount drops as a unit.
  * retirement — members finish individually ("stop"/"length"), but the
    *parent* output exists only when every lane is finished
    (:class:`GroupBook`), and an abnormal member exit ("shed", "rejected",
    "corrupted", "failed") retires the whole group with that reason.

Member rids are carved out of a reserved range (``GROUP_RID_BASE``) so they
can never collide with caller-chosen parent rids, and so journals/traces
round-trip them unambiguously.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .api import RequestOutput, SamplingParams, ServeRequest

GROUP_RID_BASE = 1 << 40     # member rid = BASE + parent_rid * LANE_STRIDE + lane
LANE_STRIDE = 256            # hard cap on lanes per group (best_of/n < 256)


def member_rid(parent_rid: int, lane: int) -> int:
    if not 0 <= lane < LANE_STRIDE:
        raise ValueError(f"lane must be in [0, {LANE_STRIDE}), got {lane}")
    return GROUP_RID_BASE + parent_rid * LANE_STRIDE + lane


def is_member_rid(rid: int) -> bool:
    return rid >= GROUP_RID_BASE


def parent_rid_of(rid: int) -> int:
    return (rid - GROUP_RID_BASE) // LANE_STRIDE


def lane_of(rid: int) -> int:
    return (rid - GROUP_RID_BASE) % LANE_STRIDE


def member_params(parent: SamplingParams, lane: int) -> SamplingParams:
    """Per-lane params: n/best_of collapse to 1 (members are ordinary
    requests), lane folds into the seed (lane 0 keeps the parent stream —
    a group of one is bitwise the parent run alone), and ``best_of`` ranking
    forces chosen-logprob recording so lanes are comparable."""
    lp = parent.logprobs
    if parent.best_of:
        lp = max(1, lp)
    return dataclasses.replace(parent, n=1, best_of=0,
                               seed=parent.seed + lane, logprobs=lp)


def expand(req: ServeRequest) -> List[ServeRequest]:
    """Expand a parent request into its member lane requests (idempotent on
    members: a request already carrying ``group >= 0`` or with group_size 1
    expands to ``[req]``)."""
    gs = req.params.group_size
    if gs <= 1 or req.group >= 0:
        return [req]
    members = []
    for lane in range(gs):
        members.append(ServeRequest(
            rid=member_rid(req.rid, lane),
            tokens=req.tokens,            # the same array: byte-identical
            #                               prompt pages for the prefix index
            params=member_params(req.params, lane),
            rclass=req.rclass, arrival=req.arrival, deadline=req.deadline,
            patches=req.patches, frames=req.frames,
            group=req.rid, lane=lane, group_size=gs))
    return members


ABNORMAL = ("rejected", "shed", "failed", "corrupted")


def _cum_logprob(req: ServeRequest) -> float:
    return float(sum(req.out_logprobs)) if req.out_logprobs else 0.0


def rank(members: Sequence[ServeRequest]) -> List[int]:
    """Member ordering for parent assembly: cumulative chosen-token logprob
    descending (the ``best_of`` criterion), lane index breaking ties — so
    without logprobs the order degenerates to lane order."""
    return sorted(range(len(members)),
                  key=lambda i: (-_cum_logprob(members[i]), members[i].lane))


def assemble(parent: ServeRequest, members: Sequence[ServeRequest],
             member_outs: Sequence[RequestOutput],
             t0: Optional[float] = None) -> RequestOutput:
    """Fold finished member lanes into the parent's terminal output.

    The parent's own stream is the winning lane's (rank 0 of the ``n`` kept
    lanes); ``group_outputs`` carries every kept member output in rank order.
    Any abnormal member exit wins over normal reasons — the joint finish
    contract: a group either completes whole or fails whole."""
    order = rank(members)
    keep = order[:parent.params.n] if parent.params.best_of else \
        sorted(order[:parent.params.n])
    abnormal = next((members[i].finish_reason for i in order
                     if members[i].finish_reason in ABNORMAL), None)
    win = members[keep[0]]
    parent.out_tokens = list(win.out_tokens)
    parent.out_logits = list(win.out_logits)
    parent.out_logprobs = list(win.out_logprobs)
    parent.out_topk = list(win.out_topk)
    parent.finish_reason = abnormal or win.finish_reason
    parent.admit_tick = min((m.admit_tick for m in members
                             if m.admit_tick >= 0), default=-1)
    parent.finish_tick = max(m.finish_tick for m in members)
    parent.preemptions = sum(m.preemptions for m in members)
    parent.replayed_tokens = sum(m.replayed_tokens for m in members)
    parent.requeue_ticks = sum(m.requeue_ticks for m in members)
    parent.prefill_tokens = sum(m.prefill_tokens for m in members)
    parent.submit_time = min((m.submit_time for m in members
                              if m.submit_time >= 0),
                             default=t0 if t0 is not None else -1.0)
    parent.finish_time = max(m.finish_time for m in members)
    out = RequestOutput(
        rid=parent.rid, new_tokens=list(parent.out_tokens),
        tokens=list(parent.out_tokens), finished=True,
        finish_reason=parent.finish_reason,
        tick=parent.finish_tick, arrival=parent.arrival,
        admit_tick=parent.admit_tick, finish_tick=parent.finish_tick,
        latency_ticks=(parent.finish_tick - parent.arrival
                       if parent.finish_tick >= 0 else None),
        wall_latency_s=parent.wall_latency_s,
        preemptions=parent.preemptions, requeue_ticks=parent.requeue_ticks)
    if parent.out_logprobs:
        out.new_logprobs = list(parent.out_logprobs)
        out.logprobs = list(parent.out_logprobs)
    if parent.out_topk:
        out.top_logprobs = list(parent.out_topk)
    out.group_outputs = [member_outs[i] for i in keep]
    return out


class GroupBook:
    """Joint-finish bookkeeping over a stream of member outputs.

    Feed every terminal member ``RequestOutput`` (plus its ``ServeRequest``)
    through :meth:`offer`; when a group's last lane lands, ``offer`` returns
    the assembled parent output. Standalone requests pass straight through as
    ``None`` (the caller already has their output)."""

    def __init__(self):
        self._parents: Dict[int, ServeRequest] = {}
        self._members: Dict[int, Dict[int, ServeRequest]] = {}
        self._outs: Dict[int, Dict[int, RequestOutput]] = {}

    def register(self, parent: ServeRequest) -> None:
        self._parents[parent.rid] = parent
        self._members.setdefault(parent.rid, {})
        self._outs.setdefault(parent.rid, {})

    def offer(self, req: ServeRequest,
              out: RequestOutput) -> Optional[RequestOutput]:
        if req.group < 0 or not out.finished:
            return None
        gid = req.group
        if gid not in self._parents:
            return None
        self._members[gid][req.lane] = req
        self._outs[gid][req.lane] = out
        parent = self._parents[gid]
        if len(self._members[gid]) < parent.params.group_size:
            return None
        lanes = sorted(self._members[gid])
        members = [self._members[gid][ln] for ln in lanes]
        outs = [self._outs[gid][ln] for ln in lanes]
        del self._parents[gid], self._members[gid], self._outs[gid]
        return assemble(parent, members, outs)

    def has(self, gid: int) -> bool:
        return gid in self._parents

    def pending(self) -> List[int]:
        return sorted(self._parents)
