"""Durable serving: write-ahead request journal + crash-consistent snapshots.

The paper's headline immune property is **memory** — responses persist after
the stimulus is gone — yet without this module every byte of serving state
(in-flight requests, emitted tokens, the pinned prefix cache, immune cost
EMAs, the router's health machine) dies with the process. PR 8's failover
survives a *replica* crash; a router crash or full-fleet power loss loses
everything. This module closes that last gap with two complementary
persistence planes, split by what each is authoritative for:

  * the **write-ahead journal** (:class:`RequestJournal`) owns *requests*:
    every accepted prompt, every emitted token, every terminal outcome, in
    arrival order. Append-only, length-prefixed + CRC-checksummed records,
    fsync'd on a configurable group-commit cadence (accepted-request records
    are fsync'd immediately — a request the fleet acknowledged is never
    lost). On open, a torn tail from a crash mid-write is truncated back to
    the last complete record.
  * the **warm snapshot** owns what was *learned* from requests: the pinned
    prefix-cache forest (token keys, adoption-value EMAs, and the pages'
    actual K/V), per-class ``ImmuneMemory`` cost EMAs, anergy levels, and
    the router's health/retry bookkeeping — written every ``snapshot_every``
    ticks through ``dist.checkpoint``'s atomic leaf-per-file machinery
    (temp dir + rename + directory fsync), so a snapshot is either wholly
    present or wholly absent, and taking one never stalls decode (it only
    *reads* device state).

Recovery composes the two: ``Router.recover(journal, snapshot)`` replays the
journal's fsync'd prefix — finished rids are reconstructed and **not**
re-run (exactly-once via journal dedup), unfinished rids re-enter through
PR 6's prefill-recompute + token-replay path, so their completed streams are
**bitwise identical** to an uninterrupted run (the ``emitted`` counter keeps
fold_in sampling keys aligned) — then imports the snapshot so the pinned
cache and immune memories resume warm instead of cold. Tokens emitted after
the last group-commit are simply re-derived: losing unsynced *emit* records
costs recompute, never correctness. A *finish* record lost the same way
means the request re-runs from its journaled token prefix and — decode being
deterministic — terminates with the identical stream, so its output still
appears exactly once.

:func:`run_durable` is the crash-restart driver: it runs a router fleet
against a trace and, on the ``poweroff`` fleet fault
(``serve.faults.PowerLoss``), discards the process state, truncates the
journal to its last fsync'd byte (the simulated page-cache loss), rebuilds a
fresh fleet, recovers, and resumes at the plan's ``restart`` tick.

Journal record format (little-endian)::

    +--------+--------+----------------------+
    | u32 len| u32 crc| payload (len bytes)  |   crc = zlib.crc32(payload)
    +--------+--------+----------------------+

Payloads are compact JSON, one of::

    {"t":"s","rid":R,"tokens":[...],"params":{...},"rclass":C,
     "arrival":A,"deadline":D}                      # submitted
    {"t":"e","rid":R,"tok":T}                       # emitted
    {"t":"f","rid":R,"reason":"stop","tick":K}      # finished

Slot-group member records additionally carry ``"group"``/``"lane"``/
``"group_size"`` plus the parent's ``"gn"``/``"gbest"`` (n / best_of), which
is everything ``Router.recover`` needs to re-register the parent and restore
joint-finish assembly across a power loss.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Optional

import numpy as np

from ..dist import checkpoint
from .api import ServeRequest
from .faults import PowerLoss

_HDR = struct.Struct("<II")          # (payload length, crc32(payload))


class RequestJournal:
    """Append-only write-ahead log of request lifecycle records.

    Opening scans any existing file, truncates a torn tail (a record whose
    header, payload, checksum, or JSON is incomplete — the footprint of a
    crash mid-write) back to the last complete record, and folds the
    surviving records into :attr:`state` for ``Router.recover``.

    Durability contract: ``log_submit`` fsyncs immediately (an acknowledged
    request is durable before anything computes on it); ``log_emit`` /
    ``log_finish`` buffer and are fsync'd by :meth:`commit` every
    ``sync_every`` ticks (group commit — one fsync amortized over a tick
    window's records). ``_synced_bytes`` tracks the durable prefix;
    :meth:`simulate_power_loss` truncates the file to it, modeling the
    kernel page cache dying with the machine."""

    def __init__(self, path: str, sync_every: int = 1):
        if sync_every < 1:
            raise ValueError(f"sync_every must be >= 1, got {sync_every}")
        self.path = path
        self.sync_every = sync_every
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self.state: dict = {}        # rid -> folded record (see _fold)
        self.records = 0             # complete records found at open
        self.truncated_bytes = 0     # torn tail dropped at open
        self._recover_tail()
        self._f = open(path, "ab")
        self._synced_bytes = self._f.tell()
        self._dirty = False
        self._last_commit_tick: Optional[int] = None
        self.appends = 0
        self.syncs = 0
        self.closed = False

    # -- open-time recovery --------------------------------------------------
    def _recover_tail(self) -> None:
        if not os.path.exists(self.path):
            return
        with open(self.path, "rb") as f:
            buf = f.read()
        good = 0
        while True:
            if len(buf) - good < _HDR.size:
                break
            length, crc = _HDR.unpack_from(buf, good)
            start, end = good + _HDR.size, good + _HDR.size + length
            if end > len(buf):
                break                            # torn payload
            payload = buf[start:end]
            if zlib.crc32(payload) != crc:
                break                            # torn/corrupt record
            try:
                rec = json.loads(payload)
            except ValueError:
                break
            self._fold(rec)
            self.records += 1
            good = end
        if good < len(buf):
            self.truncated_bytes = len(buf) - good
            with open(self.path, "r+b") as f:
                f.truncate(good)

    def _fold(self, rec: dict) -> None:
        """Fold one journal record into the per-rid recovery state."""
        rid = rec["rid"]
        if rec["t"] == "s":
            self.state.setdefault(rid, {
                "tokens": rec["tokens"], "params": rec["params"],
                "rclass": rec.get("rclass", 0),
                "arrival": rec.get("arrival", 0),
                "deadline": rec.get("deadline"),
                "group": rec.get("group", -1),
                "lane": rec.get("lane", 0),
                "group_size": rec.get("group_size", 1),
                "gn": rec.get("gn", 1), "gbest": rec.get("gbest", 0),
                "out": [], "fin": None, "fin_tick": -1})
        elif rec["t"] == "e":
            if rid in self.state:
                self.state[rid]["out"].append(rec["tok"])
        elif rec["t"] == "f":
            if rid in self.state:
                self.state[rid]["fin"] = rec["reason"]
                self.state[rid]["fin_tick"] = rec.get("tick", -1)

    # -- write path ----------------------------------------------------------
    def _append(self, rec: dict) -> None:
        if self.closed:
            raise ValueError("journal is closed")
        payload = json.dumps(rec, separators=(",", ":")).encode()
        self._f.write(_HDR.pack(len(payload), zlib.crc32(payload)))
        self._f.write(payload)
        self._dirty = True
        self.appends += 1

    def log_submit(self, req: ServeRequest,
                   parent: Optional[ServeRequest] = None) -> None:
        """Journal an accepted request; fsync'd before returning, so an
        acknowledged rid can never be lost (the 'zero lost rids' half of the
        recovery contract). A slot-group member (``req.group >= 0``) is
        journaled with its group coordinates and the parent's n/best_of, so
        recovery can re-register the parent for joint-finish assembly."""
        p = req.params
        rec = {
            "t": "s", "rid": req.rid,
            "tokens": [int(t) for t in np.asarray(req.tokens).ravel()],
            "params": {"temperature": p.temperature, "top_p": p.top_p,
                       "top_k": p.top_k, "seed": p.seed,
                       "max_new_tokens": p.max_new_tokens,
                       "stop": list(p.stop), "logprobs": p.logprobs,
                       "repetition_penalty": p.repetition_penalty,
                       "presence_penalty": p.presence_penalty,
                       "frequency_penalty": p.frequency_penalty},
            "rclass": req.rclass, "arrival": req.arrival,
            "deadline": req.deadline}
        if req.group >= 0:
            rec["group"] = req.group
            rec["lane"] = req.lane
            rec["group_size"] = req.group_size
            gp = parent.params if parent is not None else p
            rec["gn"] = gp.n
            rec["gbest"] = gp.best_of
        self._append(rec)
        self.sync()

    def log_emit(self, rid: int, tok: int) -> None:
        self._append({"t": "e", "rid": rid, "tok": int(tok)})

    def log_finish(self, rid: int, reason: str, tick: int) -> None:
        self._append({"t": "f", "rid": rid, "reason": reason,
                      "tick": int(tick)})

    def commit(self, tick: int) -> bool:
        """Group commit: fsync the buffered records if ``sync_every`` ticks
        have elapsed since the last sync (always, at cadence 1). Returns
        whether a sync happened."""
        if not self._dirty:
            self._last_commit_tick = tick
            return False
        if (self._last_commit_tick is not None
                and tick - self._last_commit_tick < self.sync_every):
            return False
        self.sync()
        self._last_commit_tick = tick
        return True

    def sync(self) -> None:
        """flush + fsync; everything appended so far becomes durable."""
        self._f.flush()
        os.fsync(self._f.fileno())
        self._synced_bytes = self._f.tell()
        self._dirty = False
        self.syncs += 1

    def close(self) -> None:
        if not self.closed:
            self.sync()
            self._f.close()
            self.closed = True

    def simulate_power_loss(self) -> None:
        """Model the machine dying: buffered + page-cache bytes (everything
        past the last fsync) vanish. The file is truncated to the durable
        prefix and the journal object becomes unusable — reopen the path to
        recover, exactly as a restarted process would."""
        try:
            self._f.close()                # flushes; the truncate below
        except OSError:                    # discards what fsync never covered
            pass
        with open(self.path, "r+b") as f:
            f.truncate(self._synced_bytes)
        self.closed = True

    def stats(self) -> dict:
        return {"records": self.records + self.appends,
                "appends": self.appends, "syncs": self.syncs,
                "synced_bytes": self._synced_bytes,
                "truncated_bytes": self.truncated_bytes,
                "sync_every": self.sync_every}


# ---------------------------------------------------------------------------
# warm snapshots — JSON meta blob + K/V leaves through dist.checkpoint
# ---------------------------------------------------------------------------
def save_snapshot(snapshot_dir: str, step: int, meta: dict, kv: list,
                  keep: int = 2) -> str:
    """Write one warm snapshot: ``meta`` (JSON-able dict — pinned forests,
    immune state, router bookkeeping) serialized into a uint8 leaf, followed
    by the pinned pages' K/V arrays, through ``checkpoint.save``'s atomic
    temp-dir + rename + dir-fsync path. ``keep=2`` retains the previous
    snapshot as the fallback ``restore_raw`` walks to on corruption."""
    blob = np.frombuffer(json.dumps(meta).encode(), np.uint8)
    return checkpoint.save(snapshot_dir, [blob] + [np.asarray(x) for x in kv],
                           step, keep=keep)


def load_snapshot(snapshot_dir: str) -> tuple[Optional[dict], list, int]:
    """Newest loadable snapshot as ``(meta, kv_leaves, step)`` —
    ``(None, [], 0)`` when the directory holds nothing usable. Driven by the
    manifest (``checkpoint.restore_raw``): the leaf count varies with how
    many pages were pinned, so there is no static ``like`` tree."""
    leaves, step = checkpoint.restore_raw(snapshot_dir)
    if not leaves:
        return None, [], 0
    meta = json.loads(bytes(np.asarray(leaves[0], np.uint8)))
    return meta, leaves[1:], step


# ---------------------------------------------------------------------------
# crash-restart driver
# ---------------------------------------------------------------------------
def run_durable(router_factory, requests: list, journal_path: str, *,
                snapshot_dir: Optional[str] = None, snapshot_every: int = 0,
                sync_every: int = 1, max_ticks: int = 10_000,
                max_restarts: int = 8) -> tuple:
    """Drive a fleet through ``requests`` surviving any scripted power loss.

    Each generation: open (and tail-recover) the journal, build a fresh
    fleet via ``router_factory()`` (which must return a ``Router``, injector
    and all — nothing in-process is reused across a power loss), attach
    durability, ``recover`` from the journal + newest snapshot, and run the
    rids the journal has never seen. A ``PowerLoss`` from the fault plan
    truncates the journal to its durable prefix and loops; the next
    generation resumes at the plan's ``restart`` tick (power-loss tick + 1
    when the plan names none). Returns ``(router, stats)`` of the final
    generation; ``stats["restarts"]`` counts the power losses survived."""
    restarts = 0
    resume_tick = 0
    while True:
        journal = RequestJournal(journal_path, sync_every=sync_every)
        router = router_factory()
        router.attach_durability(journal, snapshot_dir=snapshot_dir,
                                 snapshot_every=snapshot_every)
        if journal.state:
            router.recover(journal, snapshot_dir)
        router.tick = max(router.tick, resume_tick)
        fresh = [r for r in requests if r.rid not in journal.state]
        try:
            stats = router.run(fresh, max_ticks=max_ticks)
            journal.close()
            stats["restarts"] = restarts
            return router, stats
        except PowerLoss as pl:
            restarts += 1
            if restarts > max_restarts:
                journal.simulate_power_loss()
                raise
            journal.simulate_power_loss()
            resume_tick = (pl.restart_tick if pl.restart_tick is not None
                           else pl.tick + 1)
