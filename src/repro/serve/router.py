"""Global placement router over N engine replicas — the paper's immune
primitives lifted from per-engine admission to fleet-level load balancing.

This is the first layer of ROADMAP direction 1 (multi-host serving): a
single-process simulation harness holding ``N`` independent ``Engine``
replicas, one global arrival queue, and a per-tick router step that places
each queued request on a replica before the replicas advance in lockstep
(router tick == engine tick, so every latency stays in deterministic,
machine-independent ticks). Later PRs swap the in-process replicas for real
SPMD engine processes behind the same placement interface; the policies and
their telemetry are already the fleet-shaped ones.

Placement policies (``RouterConfig.policy``), the A/B set the routing
benchmark gates:

  * ``"rr"``  — round-robin: the memoryless baseline of the dynamic
    load-balancing taxonomy (Mandal & Pal, arXiv:1109.1650) — placement
    ignores both state and history.
  * ``"jsq"`` — join-shortest-queue: the classic state-but-no-history
    policy; place on the replica with the fewest queued+resident requests.
  * ``"immune"`` — the paper's primitives as a placement policy, three
    signals read straight off each replica's serving state:

      1. **Prefix affinity** (immune memory over KV state): the replica whose
         page pool — live shared chains or the pinned prefix cache — already
         holds the longest resident prefix of the request's prompt wins
         (``Engine.prefix_affinity``); routing there skips exactly that much
         prefill, the fleet-level form of "work the population has already
         seen is recognized and not re-paid". An affinity placement is still
         load-aware: a replica whose backlog exceeds
         ``affinity_queue_cap * num_slots`` forfeits its affinity claim, so a
         hot tenant cannot convoy one replica while the rest idle.
      2. **Anergy draining** (tolerance): a replica whose anergy level for
         the request's class exceeds ``drain_level`` is *drained* — no new
         placements of that class until IL-2 revives it locally. Placing
         there would only have the replica's own admission shed the request;
         the router moves the class's traffic to replicas still tolerant of
         it. If every replica holds the class anergic the least-anergic one
         is used (the request must land somewhere; counted in
         ``drain_overflow``).
      3. **Least remembered cost** (anticipation): with no affinity claim,
         place on the replica with the lowest *remembered* backlog — each
         queued/resident request priced at its class's cost EMA
         (``Engine.class_costs``, floored at ``cost_floor`` so cold classes
         still count as work). Per-class cost EMAs aggregated per replica are
         the load model: a replica holding two requests of a class that
         historically decodes 40 ticks is more loaded than one holding three
         5-tick chatters, which instantaneous queue length (jsq) cannot see.

Placement never changes what a request computes — admission, preemption and
replay inside each replica are untouched — so per-request tokens are bitwise
identical across policies and replica counts (the engine-vs-oneshot parity
oracle lifted one level; pinned by the placement-invariance tests and the
``routing_parity_exact`` benchmark bit).
"""
from __future__ import annotations

from collections import deque
from typing import List, NamedTuple, Optional

import numpy as np

from .api import ServeRequest
from .engine import Engine

POLICIES = ("immune", "rr", "jsq")


class RouterConfig(NamedTuple):
    policy: str = "immune"        # "immune" | "rr" | "jsq"
    drain_level: float = 0.5      # anergy level above which a replica is
    #                               drained for that class (immune policy)
    affinity_min_tokens: int = 1  # resident prompt positions before an
    #                               affinity claim beats the load model
    affinity_queue_cap: float = 2.0  # an affinity replica with more than
    #                               cap*num_slots queued+resident requests
    #                               forfeits its claim (anti-convoy)
    cost_floor: float = 1.0       # minimum per-request price in the
    #                               remembered-cost load model (cold classes)


class Router:
    """One global queue over ``engines``; ``step()`` places then advances the
    fleet one tick. Drive with :meth:`run`, read :meth:`stats`."""

    def __init__(self, engines: List[Engine], rcfg: RouterConfig = RouterConfig()):
        if not engines:
            raise ValueError("router needs at least one engine replica")
        if rcfg.policy not in POLICIES:
            raise ValueError(f"unknown router policy {rcfg.policy!r}; "
                             f"expected one of {POLICIES}")
        self.engines = list(engines)
        self.rcfg = rcfg
        self.queue: deque[ServeRequest] = deque()
        self.tick = 0
        self.submitted = 0
        self.unsubmitted = 0             # run() arrivals never reached
        self.placements = np.zeros(len(engines), np.int64)
        self.affinity_checks = 0         # immune placements that probed affinity
        self.affinity_hits = 0           # placements decided by prefix affinity
        self.affinity_tokens = 0         # resident prompt positions at those hits
        self.drain_skips = 0             # placements redirected off a drained replica
        self.drain_overflow = 0          # all replicas drained -> least-anergic
        self._rr_next = 0

    # -- placement -----------------------------------------------------------
    def _load(self, eng: Engine) -> float:
        """Remembered-cost backlog of a replica: every queued/resident request
        priced at its class's cost EMA (anticipation, not instantaneous
        occupancy)."""
        costs = eng.class_costs()
        resident = [r for r in eng.slots if r is not None]
        return float(sum(max(float(costs[r.rclass]), self.rcfg.cost_floor)
                         for r in list(eng.queue) + resident))

    def _place_immune(self, req: ServeRequest) -> int:
        n = len(self.engines)
        # 1) prefix affinity, forfeited by an over-backlogged replica
        self.affinity_checks += 1
        best_aff, best_i = 0, -1
        for i, eng in enumerate(self.engines):
            cap = self.rcfg.affinity_queue_cap * eng.ecfg.num_slots
            if eng.occupancy() > cap:
                continue
            aff = eng.prefix_affinity(req)
            if aff > best_aff:
                best_aff, best_i = aff, i
        if best_aff >= self.rcfg.affinity_min_tokens:
            self.affinity_hits += 1
            self.affinity_tokens += best_aff
            return best_i
        # 2) anergy draining: exclude replicas anergic for this class
        levels = [float(eng.anergy_levels()[req.rclass])
                  if req.rclass < eng.ecfg.num_classes else 0.0
                  for eng in self.engines]
        live = [i for i in range(n) if levels[i] <= self.rcfg.drain_level]
        if not live:                      # the request must land somewhere
            self.drain_overflow += 1
            live = [min(range(n), key=lambda i: (levels[i], i))]
        elif len(live) < n:
            self.drain_skips += 1
        # 3) least remembered cost among the live replicas
        return min(live, key=lambda i: (self._load(self.engines[i]), i))

    def _place(self, req: ServeRequest) -> int:
        """Pick the replica index for ``req`` under the configured policy."""
        if self.rcfg.policy == "rr":
            i = self._rr_next
            self._rr_next = (i + 1) % len(self.engines)
            return i
        if self.rcfg.policy == "jsq":
            return min(range(len(self.engines)),
                       key=lambda i: (self.engines[i].occupancy(), i))
        return self._place_immune(req)

    # -- driving -------------------------------------------------------------
    def submit(self, req: ServeRequest):
        """Queue a request with the router; it is placed on a replica at the
        next :meth:`step`."""
        self.queue.append(req)
        self.submitted += 1

    def step(self):
        """One fleet tick: place every queued request on a replica, then
        advance all replicas one engine tick in lockstep."""
        while self.queue:
            req = self.queue.popleft()
            i = self._place(req)
            self.placements[i] += 1
            self.engines[i].submit(req)
        for eng in self.engines:
            eng.step()
        self.tick += 1

    def _drained(self) -> bool:
        return not self.queue and all(
            not eng.queue and not eng.jobs
            and all(r is None for r in eng.slots) for eng in self.engines)

    def run(self, requests: list, max_ticks: int = 10_000) -> dict:
        """Open-loop drive mirroring ``Engine.run``: submit each request at
        its ``arrival`` tick, step until the fleet drains (or ``max_ticks``);
        returns :meth:`stats`."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        while True:
            while i < len(pending) and pending[i].arrival <= self.tick:
                self.submit(pending[i])
                i += 1
            self.unsubmitted = len(pending) - i
            if (i == len(pending) and self._drained()) \
                    or self.tick >= max_ticks:
                break
            self.step()
        return self.stats()

    # -- accounting ----------------------------------------------------------
    @property
    def completed(self) -> list:
        """All completed requests across the fleet, rid order."""
        return sorted((r for e in self.engines for r in e.completed),
                      key=lambda r: r.rid)

    def stats(self) -> dict:
        per = [eng.stats() for eng in self.engines]
        done = self.completed
        lat = np.asarray([r.latency for r in done], np.float64)
        toks = int(sum(len(r.out_tokens) for r in done))
        in_budget = sum(1 for eng in self.engines for r in eng.completed
                        if eng._met_budget(r))
        shed = sum(p["shed"] for p in per)
        rejected = sum(p["rejected"] for p in per)
        unserved = int(len(self.queue) + self.unsubmitted
                       + sum(p["unserved"] for p in per))
        demand = len(done) + shed + rejected + unserved
        empty = float("inf")
        place = self.placements
        return {
            "router": self.rcfg.policy,
            "replicas": len(self.engines),
            "ticks": self.tick,
            "completed": len(done),
            "shed": shed,
            "rejected": rejected,
            "unserved": unserved,
            "tokens": toks,
            "throughput": toks / max(self.tick, 1),
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else empty,
            "p99_latency": float(np.percentile(lat, 99)) if lat.size else empty,
            "max_latency": float(lat.max()) if lat.size else empty,
            "goodput": in_budget / max(demand, 1),
            # placement telemetry: where traffic landed and why
            "placements": [int(c) for c in place],
            "placement_imbalance": float(place.max() / max(place.mean(), 1e-9))
            if place.sum() else 0.0,
            "affinity_checks": self.affinity_checks,
            "affinity_hits": self.affinity_hits,
            "affinity_hit_rate": self.affinity_hits
            / max(self.affinity_checks, 1),
            "affinity_tokens": self.affinity_tokens,
            "drain_skips": self.drain_skips,
            "drain_overflow": self.drain_overflow,
            # fleet-aggregated engine telemetry
            "prefill_tokens": sum(p["prefill_tokens"] for p in per),
            "preemptions": sum(p["preemptions"] for p in per),
            "replayed_tokens": sum(p["replayed_tokens"] for p in per),
            "pinned_pages_adopted": sum(p["pinned_pages_adopted"] for p in per),
            "per_replica": per,
        }
