"""Global placement router over N engine replicas — the paper's immune
primitives lifted from per-engine admission to fleet-level load balancing.

This is the first layer of ROADMAP direction 1 (multi-host serving): a
single-process simulation harness holding ``N`` independent ``Engine``
replicas, one global arrival queue, and a per-tick router step that places
each queued request on a replica before the replicas advance in lockstep
(router tick == engine tick, so every latency stays in deterministic,
machine-independent ticks). Later PRs swap the in-process replicas for real
SPMD engine processes behind the same placement interface; the policies and
their telemetry are already the fleet-shaped ones.

Placement policies (``RouterConfig.policy``), the A/B set the routing
benchmark gates:

  * ``"rr"``  — round-robin: the memoryless baseline of the dynamic
    load-balancing taxonomy (Mandal & Pal, arXiv:1109.1650) — placement
    ignores both state and history.
  * ``"jsq"`` — join-shortest-queue: the classic state-but-no-history
    policy; place on the replica with the fewest queued+resident requests.
  * ``"immune"`` — the paper's primitives as a placement policy, three
    signals read straight off each replica's serving state:

      1. **Prefix affinity** (immune memory over KV state): the replica whose
         page pool — live shared chains or the pinned prefix cache — already
         holds the longest resident prefix of the request's prompt wins
         (``Engine.prefix_affinity``); routing there skips exactly that much
         prefill, the fleet-level form of "work the population has already
         seen is recognized and not re-paid". An affinity placement is still
         load-aware: a replica whose backlog exceeds
         ``affinity_queue_cap * num_slots`` forfeits its affinity claim, so a
         hot tenant cannot convoy one replica while the rest idle.
      2. **Anergy draining** (tolerance): a replica whose anergy level for
         the request's class exceeds ``drain_level`` is *drained* — no new
         placements of that class until IL-2 revives it locally. Placing
         there would only have the replica's own admission shed the request;
         the router moves the class's traffic to replicas still tolerant of
         it. If every replica holds the class anergic the least-anergic one
         is used (the request must land somewhere; counted in
         ``drain_overflow``).
      3. **Least remembered cost** (anticipation): with no affinity claim,
         place on the replica with the lowest *remembered* backlog — each
         queued/resident request priced at its class's cost EMA
         (``Engine.class_costs``, floored at ``cost_floor`` so cold classes
         still count as work). Per-class cost EMAs aggregated per replica are
         the load model: a replica holding two requests of a class that
         historically decodes 40 ticks is more loaded than one holding three
         5-tick chatters, which instantaneous queue length (jsq) cannot see.

**Health and failover** (all policies): each replica carries a health state
driven by missed step deadlines — ``healthy`` (eligible for placement),
``suspect`` after ``suspect_after`` consecutive missed fleet ticks (no *new*
placements; in-flight work stays, because a suspect replica usually
recovers), ``dead`` after ``dead_after`` (fenced: never stepped again —
declared deaths are never un-declared, a restarted process must ``rejoin``
as a fresh replica). Declaring a death triggers **failover**: the dead
replica's in-flight and queued requests are evacuated
(``Engine.evacuate``) and re-placed on survivors, where PR 6's preemption
machinery recovers them *bitwise-exactly* — re-prefill the proven prompt,
replay the recorded tokens through decode (same lane key, same fold
indices). Each re-placement spends one unit of the request's retry budget
(``max_retries``, exponential ``retry_backoff`` between attempts beyond the
first); a request that outlives its budget terminates with
``finish_reason="failed"`` — failure is an accounted outcome, never a
silently dropped rid. Requests keep their original ``arrival`` and
``submit_time`` across re-placement, so victim scoring still sees their true
seniority (a recovering request is never the "latest arrival" to evict
first) and latency accounting spans crash + replay.

**Graceful degradation** (immune replicas): while any replica is dead, the
router injects anergy stimulus for ``degrade_classes`` into every survivor
(``ImmuneAdmission.degrade``) — capacity loss is fleet-wide stress, and the
tolerance machinery sheds the classes the operator marked sheddable before
interactive traffic browns out. When capacity returns the stimulus stops and
IL-2 revives the classes in the next quiet period, the same revival path as
ordinary anergy.

Faults themselves are scripted by ``serve.faults`` (`FaultPlan` /
``FaultInjector``), which *causes* crashes/stalls/slowdowns but never
announces them — detection is this router's missed-deadline machine, as it
would be across a real IPC boundary.

Placement never changes what a request computes — admission, preemption,
replay and failover re-placement inside each replica are untouched — so
per-request tokens are bitwise identical across policies, replica counts
*and fault plans* (the engine-vs-oneshot parity oracle lifted one level;
pinned by the placement-invariance tests and the ``routing_parity_exact`` /
``failover_parity_exact`` benchmark bits).

**Durability** (``attach_durability`` / ``recover``): with a
``serve.durability.RequestJournal`` attached, ``submit`` write-ahead-logs
every accepted request (fsync'd before placement — an acknowledged rid is
never lost) and the end of each ``step`` journals the tick's emitted tokens
and terminal outcomes under one group commit, plus a warm snapshot of the
fleet's *learned* state (pinned prefix forests + K/V, immune memories,
health/retry books) every ``snapshot_every`` ticks. After a full-fleet power
loss, :meth:`Router.recover` on a fresh fleet replays the journal's durable
prefix — finished rids are reconstructed, deduplicated and **not** re-run;
unfinished rids re-enter through the prefill-recompute + token-replay path,
bitwise identical to an uninterrupted run — and imports the snapshot so the
caches and memories resume warm. See ``serve.durability`` for the formats
and ``run_durable`` for the crash-restart driver.
"""
from __future__ import annotations

import heapq
from collections import deque
from typing import List, NamedTuple, Optional

import numpy as np

import dataclasses

from . import durability as _dur
from . import groups as _groups
from .api import RequestOutput, SamplingParams, ServeRequest
from .engine import Engine

POLICIES = ("immune", "rr", "jsq")

HEALTHY, SUSPECT, DEAD = "healthy", "suspect", "dead"


class RouterConfig(NamedTuple):
    policy: str = "immune"        # "immune" | "rr" | "jsq"
    drain_level: float = 0.5      # anergy level above which a replica is
    #                               drained for that class (immune policy)
    affinity_min_tokens: int = 1  # resident prompt positions before an
    #                               affinity claim beats the load model
    affinity_queue_cap: float = 2.0  # an affinity replica with more than
    #                               cap*num_slots queued+resident requests
    #                               forfeits its claim (anti-convoy)
    cost_floor: float = 1.0       # minimum per-request price in the
    #                               remembered-cost load model (cold classes)
    suspect_after: int = 2        # consecutive missed fleet ticks before a
    #                               replica stops receiving new placements
    dead_after: int = 6           # missed ticks before it is declared dead,
    #                               fenced, and its requests re-placed (must
    #                               exceed any tolerated straggler factor)
    max_retries: int = 3          # crash re-placements per request before a
    #                               terminal finish_reason="failed"
    retry_backoff: int = 2        # ticks of exponential backoff between
    #                               re-placements beyond the first
    degrade_classes: tuple = ()   # classes shed fleet-wide while capacity is
    #                               lost (graceful degradation; empty: off)
    degrade_gain: float = 3.0     # anergy stimulus per fraction of dead
    #                               replicas (3.0: one dead of three -> full)


class Router:
    """One global queue over ``engines``; ``step()`` places then advances the
    fleet one tick. Drive with :meth:`run`, read :meth:`stats`. An optional
    ``injector`` (``serve.faults.FaultInjector``) scripts replica faults;
    health tracking and failover run regardless — a fleet without an
    injector simply never sees a missed deadline."""

    def __init__(self, engines: List[Engine],
                 rcfg: RouterConfig = RouterConfig(),
                 injector=None):
        if not engines:
            raise ValueError("router needs at least one engine replica")
        if rcfg.policy not in POLICIES:
            raise ValueError(f"unknown router policy {rcfg.policy!r}; "
                             f"expected one of {POLICIES}")
        self.engines = list(engines)
        self.rcfg = rcfg
        self.injector = injector
        self.queue: deque[ServeRequest] = deque()
        self.tick = 0
        self.submitted = 0
        self.unsubmitted = 0             # run() arrivals never reached
        self.placements = np.zeros(len(engines), np.int64)
        self.affinity_checks = 0         # immune placements that probed affinity
        self.affinity_hits = 0           # placements decided by prefix affinity
        self.affinity_tokens = 0         # resident prompt positions at those hits
        self.drain_skips = 0             # placements redirected off a drained replica
        self.drain_overflow = 0          # all replicas drained -> least-anergic
        self._rr_next = 0
        # health / failover state
        self.health: list = [HEALTHY] * len(engines)
        self.last_step: list = [-1] * len(engines)   # last fleet tick stepped
        self.fallen: List[Engine] = []   # dead engines replaced by a rejoin —
        #                                  kept so their completed requests
        #                                  stay in the fleet's books
        self.failed: list = []           # retry budget exhausted (terminal)
        self._retry: list = []           # backoff heap: (ready_tick, rid, req)
        self.deaths = 0                  # replicas declared dead
        self.rejoins = 0                 # fresh replicas swapped in
        self.death_ticks: list = []      # when each death was declared
        self.replaced_rids: set = set()  # requests ever evacuated by failover
        self.total_retries = 0           # re-placements actually performed
        # durability (attach_durability / recover)
        self.journal = None              # serve.durability.RequestJournal
        self.snapshot_dir: Optional[str] = None
        self.snapshot_every = 0
        self._journal_counts: dict = {}  # rid -> out_tokens already journaled
        self._fin_logged: set = set()    # rids with a terminal record journaled
        self.recovered: list = []        # finished requests reconstructed from
        #                                  the journal at recover() — replayed
        #                                  into the books, never re-run
        self.recovered_open = 0          # unfinished rids re-entered for replay
        self.recovered_pages = 0         # pinned pages restored warm
        self.dedup_drops = 0             # submits dropped: rid already terminal
        self.snapshots = 0               # warm snapshots written this run
        # slot groups (serve.groups): parents expand at submit, members are
        # pinned to one replica, parents assemble when every lane is terminal
        self.group_book = _groups.GroupBook()
        self.group_outputs: list = []    # assembled parent RequestOutputs
        self._group_replica: dict = {}   # gid -> replica index (co-placement)
        self._failed_groups: set = set()  # gids with a retry-exhausted member
        self.groups_submitted = 0
        self.group_coplacements = 0      # members routed by the group pin

    # -- placement -----------------------------------------------------------
    def _load(self, eng: Engine) -> float:
        """Remembered-cost backlog of a replica: every queued/resident request
        priced at its class's cost EMA (anticipation, not instantaneous
        occupancy)."""
        costs = eng.class_costs()
        resident = [r for r in eng.slots if r is not None]
        return float(sum(max(float(costs[r.rclass]), self.rcfg.cost_floor)
                         for r in list(eng.queue) + resident))

    def _eligible(self) -> list:
        """Replica indices placement may use: healthy ones. A suspect replica
        keeps its in-flight work (it usually recovers) but gets nothing new;
        a dead one is fenced. Empty when no replica is healthy — the queue
        then holds until health returns (or a rejoin arrives)."""
        return [i for i, h in enumerate(self.health) if h == HEALTHY]

    def _place_immune(self, req: ServeRequest, eligible: list) -> int:
        # 1) prefix affinity, forfeited by an over-backlogged replica
        self.affinity_checks += 1
        best_aff, best_i = 0, -1
        for i in eligible:
            eng = self.engines[i]
            cap = self.rcfg.affinity_queue_cap * eng.ecfg.num_slots
            if eng.occupancy() > cap:
                continue
            aff = eng.prefix_affinity(req)
            if aff > best_aff:
                best_aff, best_i = aff, i
        if best_aff >= self.rcfg.affinity_min_tokens:
            self.affinity_hits += 1
            self.affinity_tokens += best_aff
            return best_i
        # 2) anergy draining: exclude replicas anergic for this class
        levels = {i: float(self.engines[i].anergy_levels()[req.rclass])
                  if req.rclass < self.engines[i].ecfg.num_classes else 0.0
                  for i in eligible}
        live = [i for i in eligible if levels[i] <= self.rcfg.drain_level]
        if not live:                      # the request must land somewhere
            self.drain_overflow += 1
            live = [min(eligible, key=lambda i: (levels[i], i))]
        elif len(live) < len(eligible):
            self.drain_skips += 1
        # 3) least remembered cost among the live replicas
        return min(live, key=lambda i: (self._load(self.engines[i]), i))

    def _place(self, req: ServeRequest) -> int:
        """Pick the replica index for ``req`` under the configured policy
        (healthy replicas only; -1 when none is). With every replica healthy
        each policy behaves exactly as it did without health tracking.

        Slot-group members are pinned: the first member placed decides the
        replica for the whole group (prefix sharing, cascade preemption and
        joint cancellation are all per-engine machinery — splitting a group
        across replicas would forfeit every one of them). A later member whose
        pinned replica has gone suspect holds in the queue rather than defect;
        a death clears the pin and the group re-places together."""
        if req.group >= 0:
            j = self._group_replica.get(req.group, -1)
            if j >= 0:
                if self.health[j] == HEALTHY:
                    self.group_coplacements += 1
                    return j
                return -1
        i = self._place_policy(req)
        if req.group >= 0 and i >= 0:
            self._group_replica[req.group] = i
        return i

    def _place_policy(self, req: ServeRequest) -> int:
        eligible = self._eligible()
        if not eligible:
            return -1
        if self.rcfg.policy == "rr":
            for _ in range(len(self.engines)):   # skip fenced/suspect slots
                i = self._rr_next
                self._rr_next = (i + 1) % len(self.engines)
                if self.health[i] == HEALTHY:
                    return i
            return eligible[0]
        if self.rcfg.policy == "jsq":
            return min(eligible,
                       key=lambda i: (self.engines[i].occupancy(), i))
        return self._place_immune(req, eligible)

    # -- health / failover ---------------------------------------------------
    def _declare_dead(self, i: int) -> None:
        """Fence replica ``i`` and fail its work over to the survivors. The
        evacuated request objects carry everything recovery needs (prompt +
        recorded tokens); re-admission elsewhere replays them bitwise. Each
        evacuation costs a retry; past ``max_retries`` the request terminates
        with ``finish_reason="failed"`` instead of bouncing forever."""
        self.health[i] = DEAD
        self.deaths += 1
        self.death_ticks.append(self.tick)
        for gid, rep in list(self._group_replica.items()):
            if rep == i:               # the group re-places (together) on a
                del self._group_replica[gid]   # survivor
        evacuated = list(self.engines[i].evacuate())
        for req in evacuated:
            self.replaced_rids.add(req.rid)
            req.retries += 1
            if req.retries > self.rcfg.max_retries:
                self._fail(req)
        for req in evacuated:
            if req.finish_reason == "failed":
                continue
            if req.group >= 0 and req.group in self._failed_groups:
                self._fail(req)        # joint retirement: a sibling exhausted
                continue               # its budget, the group fails whole
            self.total_retries += 1
            if req.admit_tick >= 0 and req.preempt_tick < 0:
                # held a slot: its re-queue wait is accounted like a
                # preemption's (requeue_ticks on re-admission)
                req.preempt_tick = self.tick
            delay = 0 if req.retries == 1 else \
                self.rcfg.retry_backoff * (1 << (req.retries - 2))
            if delay > 0:
                heapq.heappush(self._retry,
                               (self.tick + 1 + delay, req.rid, req))
            else:
                self.queue.append(req)

    def _fail(self, req: ServeRequest) -> None:
        """Terminal ``finish_reason="failed"``; a member's failure marks the
        whole group so its siblings fail jointly wherever they currently are
        (evacuation batch, retry backoff, or the router queue)."""
        req.finish_reason = "failed"
        req.finish_tick = self.tick
        self.failed.append(req)
        if req.group >= 0:
            self._failed_groups.add(req.group)

    def _check_health(self) -> None:
        """End-of-tick health transitions from missed step deadlines. Death
        is detected, never announced — a crashed replica just stops stepping,
        and this is the only place the fleet finds out."""
        for i in range(len(self.engines)):
            if self.health[i] == DEAD:
                continue
            missed = self.tick - self.last_step[i]
            if missed >= self.rcfg.dead_after:
                self._declare_dead(i)
            elif missed >= self.rcfg.suspect_after:
                self.health[i] = SUSPECT
            else:
                self.health[i] = HEALTHY

    def _degrade(self) -> None:
        """While capacity is down, shed the operator-marked classes on every
        survivor: anergy stimulus scaled by the dead fraction of the fleet,
        reapplied each tick so the brown-out tracks the outage and IL-2
        revival takes over the moment it ends."""
        if not self.rcfg.degrade_classes:
            return
        dead = sum(1 for h in self.health if h == DEAD)
        if not dead:
            return
        sev = min(1.0, self.rcfg.degrade_gain * dead / len(self.engines))
        for i, eng in enumerate(self.engines):
            if self.health[i] != DEAD and eng.admission is not None:
                eng.admission.degrade(self.rcfg.degrade_classes, sev)

    def rejoin(self, i: int, engine: Engine) -> None:
        """Swap a *fresh* engine into replica slot ``i`` (a restarted
        process: cold pinned cache, blank immune state). A replica is
        replaced, never resumed — whatever the old process held is gone; if
        the health machine had not yet declared the death (a fast restart),
        it is declared now so the old in-flight work is recovered first. The
        newcomer starts healthy with a fresh deadline clock; prefix-affinity
        traffic rewarms its pinned cache from the live traffic stream."""
        if self.health[i] != DEAD:
            self._declare_dead(i)
        self.fallen.append(self.engines[i])
        self.engines[i] = engine
        engine.tick = self.tick
        self.health[i] = HEALTHY
        self.last_step[i] = self.tick - 1
        self.rejoins += 1

    # -- driving -------------------------------------------------------------
    def submit(self, req: ServeRequest):
        """Queue a request with the router; it is placed on a replica at the
        next :meth:`step`. With a journal attached the request is
        write-ahead-logged (and fsync'd) before it can be placed, and a rid
        the journal already holds a terminal record for is dropped — the
        exactly-once half of the recovery contract (a re-driven trace can
        never duplicate a completion).

        A group parent (``n``/``best_of`` > 1) expands here: the *members*
        are what the fleet journals, places and schedules; the parent is
        registered with the router's :class:`serve.groups.GroupBook` and its
        output assembles when the last lane lands. Expansion is deterministic
        (member rids derive from the parent rid), so a re-driven trace's
        members dedup against the journal exactly like plain rids."""
        if req.params.group_size > 1 and req.group < 0:
            members = _groups.expand(req)
            if all(m.rid in self._fin_logged for m in members):
                self.dedup_drops += 1
                return
            self.group_book.register(req)
            self.groups_submitted += 1
            for m in members:
                self._submit_one(m, parent=req)
            return
        self._submit_one(req)

    def _submit_one(self, req: ServeRequest,
                    parent: Optional[ServeRequest] = None):
        if self.journal is not None:
            if req.rid in self._fin_logged:
                self.dedup_drops += 1
                return
            if req.rid in self._journal_counts:
                return                 # already recovered open — re-queued by
                #                        recover(), not by re-submission
            self.journal.log_submit(req, parent=parent)
            self._journal_counts[req.rid] = len(req.out_tokens)
        self.queue.append(req)
        self.submitted += 1

    def step(self):
        """One fleet tick: fire scripted faults, release expired retry
        backoffs, place every queued request on a healthy replica, advance
        the non-fenced replicas in lockstep (minus those the injector holds
        back), then run the health machine and the degradation signal."""
        if self.injector is not None:
            self.injector.begin_tick(self)
        while self._retry and self._retry[0][0] <= self.tick:
            self.queue.append(heapq.heappop(self._retry)[2])
        while self.queue:
            req = self.queue[0]
            if req.group >= 0 and req.group in self._failed_groups:
                self.queue.popleft()   # joint retirement: a sibling already
                self._fail(req)        # failed, this lane never re-places
                continue
            i = self._place(req)
            if i < 0:                  # no healthy replica: hold the queue
                break
            self.queue.popleft()
            self.placements[i] += 1
            self.engines[i].submit(req)
        for i, eng in enumerate(self.engines):
            if self.health[i] == DEAD:
                continue               # fenced: a dead replica never steps
            # lockstep clock: even a held-back replica's tick tracks the
            # fleet's, so tick latencies stay fleet-global through stalls,
            # slowdowns and rejoins
            eng.tick = self.tick
            if self.injector is None or self.injector.can_step(i, self.tick):
                eng.step()
                self.last_step[i] = self.tick
        self._check_health()
        self._degrade()
        self._assemble_groups()
        if self.journal is not None:
            self._journal_tick()
        self.tick += 1

    # -- slot groups ---------------------------------------------------------
    def _member_output(self, req: ServeRequest) -> RequestOutput:
        """Terminal RequestOutput for a group member, for parent assembly.
        The fleet drives engines with ``step()`` rather than ``stream()``, so
        member outputs are built here from the retired request objects."""
        done = req.finish_reason in ("stop", "length")
        return RequestOutput(
            rid=req.rid, new_tokens=[], tokens=list(req.out_tokens),
            finished=True, finish_reason=req.finish_reason,
            tick=req.finish_tick, arrival=req.arrival,
            admit_tick=req.admit_tick, finish_tick=req.finish_tick,
            latency_ticks=req.latency if done else None,
            wall_latency_s=req.wall_latency_s if done else None,
            logprobs=list(req.out_logprobs) if req.out_logprobs else None,
            top_logprobs=list(req.out_topk) if req.out_topk else None,
            preemptions=req.preemptions, requeue_ticks=req.requeue_ticks)

    def _assemble_groups(self) -> None:
        """Offer every terminal member the fleet knows about to the group
        book; a parent whose last lane has landed assembles into
        :attr:`group_outputs` (joint finish — an abnormal lane fails the
        whole group). Idempotent: an assembled gid absorbs re-offers
        silently, so scanning the terminal books each tick is safe."""
        if not self.group_book.pending():
            return
        for req in list(self._terminal_requests()) + self.recovered:
            if req.group < 0:
                continue
            done = self.group_book.offer(req, self._member_output(req))
            if done is not None:
                self.group_outputs.append(done)
                self._group_replica.pop(req.group, None)

    def _drained(self) -> bool:
        return not self.queue and not self._retry and all(
            not eng.queue and not eng.jobs
            and all(r is None for r in eng.slots) for eng in self.engines)

    def run(self, requests: list, max_ticks: int = 10_000) -> dict:
        """Open-loop drive mirroring ``Engine.run``: submit each request at
        its ``arrival`` tick, step until the fleet drains (or ``max_ticks``);
        returns :meth:`stats`."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        i = 0
        while True:
            while i < len(pending) and pending[i].arrival <= self.tick:
                self.submit(pending[i])
                i += 1
            self.unsubmitted = len(pending) - i
            if (i == len(pending) and self._drained()) \
                    or self.tick >= max_ticks:
                break
            self.step()
        return self.stats()

    # -- durability ----------------------------------------------------------
    def attach_durability(self, journal, snapshot_dir: Optional[str] = None,
                          snapshot_every: int = 0) -> None:
        """Arm the write-ahead journal (and, optionally, a warm-snapshot
        cadence) on this router. Call before driving; ``run_durable`` does."""
        self.journal = journal
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)

    def _terminal_requests(self):
        """Every request the fleet has retired with a terminal reason —
        completions, sheds, rejections, corruptions across live + fallen
        replicas, plus the router's own retry-exhausted failures."""
        for eng in self.engines + self.fallen:
            yield from eng.completed
            yield from eng.shed
            yield from eng.rejected
            yield from eng.corrupted
        yield from self.failed

    def _journal_emits(self, req: ServeRequest) -> None:
        n = self._journal_counts.get(req.rid, 0)
        for tok in req.out_tokens[n:]:
            self.journal.log_emit(req.rid, int(tok))
        if len(req.out_tokens) > n:
            self._journal_counts[req.rid] = len(req.out_tokens)

    def _journal_tick(self) -> None:
        """End-of-tick journal pass: append this tick's emitted tokens (the
        delta past each rid's journaled count) and any new terminal records,
        then group-commit; every ``snapshot_every`` ticks, also write the
        warm snapshot. Losing an unsynced emit costs replay recompute, never
        correctness — decode re-derives the identical token."""
        for eng in self.engines:
            for req in eng.slots:
                if req is not None and req.rid in self._journal_counts:
                    self._journal_emits(req)
        for req in self._terminal_requests():
            if req.rid in self._fin_logged \
                    or req.rid not in self._journal_counts:
                continue
            self._journal_emits(req)
            self.journal.log_finish(req.rid, req.finish_reason or "stop",
                                    req.finish_tick)
            self._fin_logged.add(req.rid)
        self.journal.commit(self.tick)
        if (self.snapshot_dir and self.snapshot_every
                and self.tick and self.tick % self.snapshot_every == 0):
            self._save_snapshot()

    def _save_snapshot(self) -> None:
        """Snapshot the fleet's learned state + the router's failover books.
        Request state is deliberately absent (the journal owns it); the
        per-rid retry counts ride along so a recovered request keeps its
        spent budget. Export only reads device state — no decode stall."""
        metas, kv = [], []
        for eng in self.engines:
            m, k = eng.export_warm_state()
            metas.append(m)
            kv.extend(k)
        open_reqs = [r for eng in self.engines
                     for r in list(eng.queue)
                     + [j.req for j in eng.jobs]
                     + [s for s in eng.slots if s is not None]] \
            + list(self.queue) + [r for _, _, r in self._retry]
        blob = {
            "tick": self.tick,
            "policy": self.rcfg.policy,
            "replicas": metas,
            "router": {
                "deaths": self.deaths,
                "rejoins": self.rejoins,
                "death_ticks": list(self.death_ticks),
                "replaced_rids": sorted(self.replaced_rids),
                "total_retries": self.total_retries,
                "retries": {str(r.rid): r.retries
                            for r in open_reqs if r.retries},
            },
        }
        _dur.save_snapshot(self.snapshot_dir, self.tick, blob, kv)
        self.snapshots += 1

    def recover(self, journal, snapshot: Optional[str] = None) -> dict:
        """Rebuild this (fresh) fleet from a recovered journal plus the
        newest warm snapshot. Journal-finished rids become reconstructed
        request objects in :attr:`recovered` — in the books, never re-run
        (exactly-once). Unfinished rids are rebuilt with their journaled
        token prefix and re-enqueued in ``(arrival, rid)`` order; admission
        re-prefills their proven prompt and replays the recorded tokens
        through decode, so their eventual streams are bitwise identical to a
        run that never lost power. The snapshot re-pins the prefix forest
        (K/V scattered straight back — zero recompute), resumes the immune
        EMAs, and restores the failover books. ``submit_time`` is NOT
        restored: ``perf_counter`` is process-relative, so a pre-loss wall
        clock would be meaningless here."""
        if self.journal is None:
            self.attach_durability(journal)
        sdir = snapshot if snapshot is not None else self.snapshot_dir
        blob, kv, _ = _dur.load_snapshot(sdir) if sdir else (None, [], 0)
        retries: dict = {}
        if blob is not None:
            rb = blob.get("router") or {}
            self.deaths = int(rb.get("deaths") or 0)
            self.rejoins = int(rb.get("rejoins") or 0)
            self.death_ticks = list(rb.get("death_ticks") or [])
            self.replaced_rids = set(rb.get("replaced_rids") or [])
            self.total_retries = int(rb.get("total_retries") or 0)
            retries = {int(k): int(v)
                       for k, v in (rb.get("retries") or {}).items()}
            off = 0
            for i, m in enumerate(blob.get("replicas") or []):
                n = len(m.get("forest") or []) * int(m.get("kv_per_page") or 0)
                if i < len(self.engines):
                    self.recovered_pages += \
                        self.engines[i].import_warm_state(m, kv[off:off + n])
                off += n
            self.tick = max(self.tick, int(blob.get("tick") or 0))
        reopen = []
        for rid, rec in sorted(journal.state.items()):
            req = ServeRequest(
                rid=rid, tokens=np.asarray(rec["tokens"], np.int32),
                params=SamplingParams(**rec["params"]),
                rclass=int(rec.get("rclass") or 0),
                arrival=int(rec.get("arrival") or 0),
                deadline=rec.get("deadline"),
                group=int(rec.get("group", -1)),
                lane=int(rec.get("lane", 0)),
                group_size=int(rec.get("group_size", 1)))
            if req.group >= 0 and not self.group_book.has(req.group):
                # rebuild the parent from the member record's group metadata
                # and re-arm joint-finish assembly across the power loss
                pparams = dataclasses.replace(
                    req.params, n=int(rec.get("gn", 1)),
                    best_of=int(rec.get("gbest", 0)),
                    seed=req.params.seed - req.lane)
                parent = ServeRequest(
                    rid=req.group, tokens=req.tokens, params=pparams,
                    rclass=req.rclass, arrival=req.arrival,
                    deadline=req.deadline)
                self.group_book.register(parent)
                self.groups_submitted += 1
            req.out_tokens = list(rec["out"])
            self._journal_counts[rid] = len(req.out_tokens)
            if rec["fin"] is not None:
                req.finish_reason = rec["fin"]
                req.finish_tick = int(rec.get("fin_tick", -1))
                self._fin_logged.add(rid)
                self.recovered.append(req)
            else:
                req.retries = retries.get(rid, 0)
                reopen.append(req)
        for req in sorted(reopen, key=lambda r: (r.arrival, r.rid)):
            self.queue.append(req)
            self.submitted += 1
        self.recovered_open += len(reopen)
        return {"recovered_open": len(reopen),
                "recovered_finished": len(self.recovered),
                "recovered_pages": self.recovered_pages}

    # -- accounting ----------------------------------------------------------
    @property
    def completed(self) -> list:
        """All completed requests across the fleet — replaced (fallen)
        replicas included, their pre-crash completions are real, as are
        journal-recovered completions from before a power loss — rid
        order."""
        rec = [r for r in self.recovered
               if r.finish_reason in ("stop", "length")]
        return sorted((r for src in ([e.completed for e in
                                      self.engines + self.fallen] + [rec])
                       for r in src), key=lambda r: r.rid)

    def stats(self) -> dict:
        fleet = self.engines + self.fallen
        per = [eng.stats() for eng in self.engines]
        done = self.completed
        lat = np.asarray([r.latency for r in done], np.float64)
        toks = int(sum(len(r.out_tokens) for r in done))
        # journal-recovered completions are judged against the live fleet's
        # (uniform) tick budget; their wall clock did not survive the restart
        in_budget = sum(1 for eng in fleet for r in eng.completed
                        if eng._met_budget(r)) \
            + sum(1 for r in self.recovered
                  if r.finish_reason in ("stop", "length")
                  and self.engines[0]._met_budget(r))
        rec_by = {}
        for r in self.recovered:
            rec_by[r.finish_reason] = rec_by.get(r.finish_reason, 0) + 1
        shed = sum(len(eng.shed) for eng in fleet) + rec_by.get("shed", 0)
        rejected = sum(len(eng.rejected) for eng in fleet) \
            + rec_by.get("rejected", 0)
        corrupted = sum(len(eng.corrupted) for eng in fleet) \
            + rec_by.get("corrupted", 0)
        unserved = int(len(self.queue) + len(self._retry) + self.unsubmitted
                       + sum(p["unserved"] for p in per))
        failed = len(self.failed) + rec_by.get("failed", 0)
        demand = len(done) + shed + rejected + corrupted + unserved + failed
        # recovery: from the first declared death to the last re-placed
        # request's completion — how long the failover took to fully absorb
        redone = [r for r in done if r.rid in self.replaced_rids]
        recovery = (max(r.finish_tick for r in redone)
                    - min(self.death_ticks)) \
            if redone and self.death_ticks else 0
        empty = float("inf")
        place = self.placements
        return {
            "router": self.rcfg.policy,
            "replicas": len(self.engines),
            "ticks": self.tick,
            "completed": len(done),
            "shed": shed,
            "rejected": rejected,
            "corrupted": corrupted,
            "unserved": unserved,
            "failed": failed,
            "tokens": toks,
            "throughput": toks / max(self.tick, 1),
            "p50_latency": float(np.percentile(lat, 50)) if lat.size else empty,
            "p99_latency": float(np.percentile(lat, 99)) if lat.size else empty,
            "max_latency": float(lat.max()) if lat.size else empty,
            "goodput": in_budget / max(demand, 1),
            # placement telemetry: where traffic landed and why
            "placements": [int(c) for c in place],
            "placement_imbalance": float(place.max() / max(place.mean(), 1e-9))
            if place.sum() else 0.0,
            "affinity_checks": self.affinity_checks,
            "affinity_hits": self.affinity_hits,
            "affinity_hit_rate": self.affinity_hits
            / max(self.affinity_checks, 1),
            "affinity_tokens": self.affinity_tokens,
            "drain_skips": self.drain_skips,
            "drain_overflow": self.drain_overflow,
            # health / failover telemetry
            "health": list(self.health),
            "deaths": self.deaths,
            "rejoins": self.rejoins,
            "replaced_requests": len(self.replaced_rids),
            "retries": self.total_retries,
            "recovery_ticks": int(recovery),
            "faults": self.injector.stats()
            if self.injector is not None else None,
            # durability telemetry (None journal -> all-zero block)
            "durability": {
                "journal": self.journal.stats()
                if self.journal is not None else None,
                "recovered_finished": len(self.recovered),
                "recovered_open": self.recovered_open,
                "recovered_pinned_pages": self.recovered_pages,
                "dedup_drops": self.dedup_drops,
                "snapshots": self.snapshots,
            },
            # slot-group telemetry
            "groups": {
                "submitted": self.groups_submitted,
                "assembled": len(self.group_outputs),
                "pending": len(self.group_book.pending()),
                "coplacements": self.group_coplacements,
                "failed_groups": len(self._failed_groups),
            },
            # fleet-aggregated engine telemetry
            "prefill_tokens": sum(p["prefill_tokens"] for p in per),
            "preemptions": sum(p["preemptions"] for p in per),
            "replayed_tokens": sum(p["replayed_tokens"] for p in per),
            "pinned_pages_adopted": sum(p["pinned_pages_adopted"] for p in per),
            "per_replica": per,
        }
