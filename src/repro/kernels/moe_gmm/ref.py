"""Pure-jnp oracle for the grouped matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def gmm_ref(x, w, group_sizes):
    """x: (E, C, D); w: (E, D, F); rows >= group_sizes[e] are zeroed."""
    e, c, d = x.shape
    mask = jnp.arange(c)[None, :, None] < group_sizes[:, None, None]
    out = jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jnp.where(mask, out, 0.0).astype(x.dtype)
