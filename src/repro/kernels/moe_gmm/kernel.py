"""Pallas TPU grouped matmul for MoE expert compute: (E, C, D) x (E, D, F) -> (E, C, F).

Tiling: grid = (E, C/bc, F/bf, D/bd); a (bc, bf) fp32 accumulator lives in VMEM
scratch across the (sequential, innermost) D dimension. ``group_sizes`` carries the
*ragged* occupancy of each expert's capacity buffer: row blocks entirely beyond an
expert's live rows are skipped structurally — the kernel does no MXU work for
padding, which is where the load-balancing win (immune router -> even group sizes ->
no straggler expert tile) becomes wall-clock time on TPU.

Block shapes default to MXU-aligned (128, 128) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(sizes_ref, x_ref, w_ref, o_ref, acc_scr, *, bc: int, bd: int, nd: int):
    i = pl.program_id(1)          # row (capacity) block
    kd = pl.program_id(3)         # contraction block (sequential innermost)

    @pl.when(kd == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    live = sizes_ref[0] > i * bc  # ragged skip: no live rows in this block

    @pl.when(live)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                    # (bc, bd)
        w = w_ref[0].astype(jnp.float32)                    # (bd, bf)
        acc_scr[...] += jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "bf", "bd", "interpret"))
def moe_gmm(x, w, group_sizes, *, bc: int = 128, bf: int = 128, bd: int = 128,
            interpret: bool = True):
    """x: (E, C, D); w: (E, D, F); group_sizes: (E,) int32 live rows per expert."""
    e, c, d = x.shape
    f = w.shape[-1]
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (x.shape, w.shape)
    nc, nf, nd = c // bc, f // bf, d // bd

    kernel = functools.partial(_kernel, bc=bc, bd=bd, nd=nd)
    return pl.pallas_call(
        kernel,
        grid=(e, nc, nf, nd),
        in_specs=[
            pl.BlockSpec((1,), lambda e_, i, j, kd: (e_,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bc, bd), lambda e_, i, j, kd: (e_, i, kd)),
            pl.BlockSpec((1, bd, bf), lambda e_, i, j, kd: (e_, kd, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e_, i, j, kd: (e_, i, j)),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(group_sizes, x, w)
