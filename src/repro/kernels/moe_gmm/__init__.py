from .ops import gmm_ref, moe_gmm  # noqa: F401
