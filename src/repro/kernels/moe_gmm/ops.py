"""jit'd public wrapper for the grouped matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import moe_gmm as _kernel_call
from .ref import gmm_ref


def moe_gmm(x, w, group_sizes=None, *, bc: int = 128, bf: int = 128,
            bd: int = 128, interpret: bool | None = None):
    if group_sizes is None:
        group_sizes = jnp.full((x.shape[0],), x.shape[1], jnp.int32)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _kernel_call(x, w, group_sizes.astype(jnp.int32),
                        bc=bc, bf=bf, bd=bd, interpret=interpret)


__all__ = ["moe_gmm", "gmm_ref"]
