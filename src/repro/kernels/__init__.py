"""Pallas TPU kernels (validated interpret=True on CPU): each subpackage carries
kernel.py (pl.pallas_call + BlockSpec tiling), ops.py (jit'd wrapper), ref.py
(pure-jnp oracle)."""
from . import flash_attention, grid_step, moe_gmm, paged_attention  # noqa: F401
