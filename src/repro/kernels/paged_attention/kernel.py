"""Pallas TPU paged-decode attention: block-table K/V gather inside the kernel.

One query token per sequence (the serving engine's decode tick) attends over a
KV cache scattered across fixed-size physical pages. The block table and the
per-sequence lengths ride in as *scalar prefetch* (``PrefetchScalarGridSpec``),
so the BlockSpec index maps pick each logical page's physical page id before
the kernel body runs — the gather is the DMA schedule itself; no
(B, maxp*page, ...) contiguous K/V tensor ever exists in HBM.

Tiling: grid = (B, Hkv, maxp) with the logical-page dimension innermost and
sequential; the (m, l, acc) online-softmax state lives in VMEM scratch and
persists across page steps, exactly like the flash kernel's KV loop. The whole
GQA group of a kv head is one q block, so each grid step is a
(G, d) x (d, page) MXU tile. Pages entirely beyond a sequence's length are
skipped structurally (``pl.when``) — ragged page counts cost no compute, and
null-page (unmapped) table entries are never read live.

Layouts: q (B, Hkv, G, D); k_pages, v_pages (P, page, Hkv, D);
table (B, maxp) int32 physical page ids; lengths (B,) int32 valid positions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
            acc_scr, *, page: int, maxp: int):
    b = pl.program_id(0)
    j = pl.program_id(2)          # logical page (sequential innermost)
    length = len_ref[b]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # structural skip: the whole page is beyond this sequence's length
    @pl.when(j * page < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (G, d)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (page, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / (q.shape[-1] ** 0.5))                 # (G, page)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == maxp - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _verify_kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr,
                   l_scr, acc_scr, *, page: int, maxp: int, sq: int, g: int):
    """k-position verify step: ``sq`` query rows per sequence, row ``r``
    (query position ``pos + r // g`` for GQA group lane ``r % g``) attends the
    causal prefix ``kpos <= pos + r // g``. Same online-softmax page loop as
    the 1-query decode kernel — the rows just carry a per-row causal bound
    instead of one shared length."""
    b = pl.program_id(0)
    j = pl.program_id(2)          # logical page (sequential innermost)
    pos = pos_ref[b]              # first query row's cache position

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # structural skip: the page is beyond even the deepest query row's bound
    @pl.when(j * page <= pos + (sq - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (sq*g, d)
        k = k_ref[0, :, 0].astype(jnp.float32)               # (page, d)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / (q.shape[-1] ** 0.5))                 # (sq*g, page)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qrow = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
        s = jnp.where(kpos <= pos + qrow, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == maxp - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sq", "interpret"))
def paged_attention_verify(q, k_pages, v_pages, table, pos, *, sq: int,
                           interpret: bool = True):
    """q: (B, Hkv, Sq*G, D) — ``sq`` query rows per kv head, (query, group)
    row-major; k_pages, v_pages: (P, page, Hkv, D); table: (B, maxp) int32;
    pos: (B,) int32 first query row's cache position -> (B, Hkv, Sq*G, D).
    Row ``r`` attends causally up to position ``pos + r // G`` — the batched
    verify step of self-speculative decoding (sq == 1 is exactly the decode
    kernel's contract with lengths = pos + 1)."""
    b, hk, sqg, d = q.shape
    page = k_pages.shape[1]
    maxp = table.shape[1]
    g = sqg // sq

    kernel = functools.partial(_verify_kernel, page=page, maxp=maxp, sq=sq,
                               g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, sqg, d),
                         lambda b_, h_, j, tbl, ps: (b_, h_, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, tbl, ps: (tbl[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, tbl, ps: (tbl[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, sqg, d),
                               lambda b_, h_, j, tbl, ps: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sqg,), jnp.float32),     # running max m
            pltpu.VMEM((sqg,), jnp.float32),     # running sum l
            pltpu.VMEM((sqg, d), jnp.float32),   # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, sqg, d), q.dtype),
        interpret=interpret,
    )(table, pos, q, k_pages, v_pages)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, table, lengths, *,
                    interpret: bool = True):
    """q: (B, Hkv, G, D); k_pages, v_pages: (P, page, Hkv, D);
    table: (B, maxp) int32; lengths: (B,) int32 -> (B, Hkv, G, D)."""
    b, hk, g, d = q.shape
    page = k_pages.shape[1]
    maxp = table.shape[1]

    kernel = functools.partial(_kernel, page=page, maxp=maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hk, maxp),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b_, h_, j, tbl, lens: (b_, h_, 0, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, tbl, lens: (tbl[b_, j], 0, h_, 0)),
            pl.BlockSpec((1, page, 1, d),
                         lambda b_, h_, j, tbl, lens: (tbl[b_, j], 0, h_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b_, h_, j, tbl, lens: (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),       # running max m
            pltpu.VMEM((g,), jnp.float32),       # running sum l
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hk, g, d), q.dtype),
        interpret=interpret,
    )(table, lengths, q, k_pages, v_pages)
