"""jit'd public wrapper: picks the Pallas kernel on TPU, interpret mode elsewhere."""
from __future__ import annotations

import jax

from .kernel import paged_attention as _kernel_call
from .kernel import paged_attention_verify as _verify_call
from .ref import paged_attention_ref, paged_attention_verify_ref


def paged_attention(q, k_pages, v_pages, table, lengths, *,
                    interpret: bool | None = None):
    """q: (B, H, D); k_pages, v_pages: (P, page, Hkv, D); table: (B, maxp) i32;
    lengths: (B,) i32. interpret=None -> auto (True off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, d = q.shape
    hk = k_pages.shape[2]
    out = _kernel_call(q.reshape(b, hk, h // hk, d), k_pages, v_pages,
                       table, lengths, interpret=interpret)
    return out.reshape(b, h, d)


def paged_attention_verify(q, k_pages, v_pages, table, pos, *,
                           interpret: bool | None = None):
    """Batched k-position verify step (self-speculative decoding).

    q: (B, Sq, H, D) — query row ``r`` sits at cache position ``pos + r`` and
    attends causally up to it; k_pages, v_pages: (P, page, Hkv, D); table:
    (B, maxp) i32; pos: (B,) i32. Returns (B, Sq, H, D).
    interpret=None -> auto (True off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    hk = k_pages.shape[2]
    g = h // hk
    # (B, Sq, Hkv, G, D) -> (B, Hkv, Sq, G, D) -> (B, Hkv, Sq*G, D): rows of
    # one kv head are (query, group) row-major, matching the kernel's r // G
    qk = q.reshape(b, sq, hk, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hk, sq * g, d)
    out = _verify_call(qk, k_pages, v_pages, table, pos, sq=sq,
                       interpret=interpret)
    return out.reshape(b, hk, sq, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, sq, h, d)


__all__ = ["paged_attention", "paged_attention_ref",
           "paged_attention_verify", "paged_attention_verify_ref"]
