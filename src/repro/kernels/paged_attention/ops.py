"""jit'd public wrapper: picks the Pallas kernel on TPU, interpret mode elsewhere."""
from __future__ import annotations

import jax

from .kernel import paged_attention as _kernel_call
from .ref import paged_attention_ref


def paged_attention(q, k_pages, v_pages, table, lengths, *,
                    interpret: bool | None = None):
    """q: (B, H, D); k_pages, v_pages: (P, page, Hkv, D); table: (B, maxp) i32;
    lengths: (B,) i32. interpret=None -> auto (True off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, d = q.shape
    hk = k_pages.shape[2]
    out = _kernel_call(q.reshape(b, hk, h // hk, d), k_pages, v_pages,
                       table, lengths, interpret=interpret)
    return out.reshape(b, h, d)


__all__ = ["paged_attention", "paged_attention_ref"]
