"""Pure-jnp oracle for the paged-decode attention kernel: dense gather."""
from __future__ import annotations

import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, table, lengths):
    """q: (B, H, D); k_pages, v_pages: (P, page, Hkv, D); table: (B, maxp) i32;
    lengths: (B,) i32 -> (B, H, D), fp32 math.

    Gathers each sequence's pages into the dense (maxp*page, Hkv, D) logical
    layout, then runs masked single-query attention — the same contract the
    XLA fallback in ``models.layers.attention_decode_paged`` implements."""
    b, h, d = q.shape
    page = k_pages.shape[1]
    maxp = table.shape[1]
    hk = k_pages.shape[2]
    g = h // hk

    k = k_pages[table].reshape(b, maxp * page, hk, d).astype(jnp.float32)
    v = v_pages[table].reshape(b, maxp * page, hk, d).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, hk, g, d)

    scores = jnp.einsum("bkgd,btkd->bkgt", qf, k) / (d ** 0.5)
    kpos = jnp.arange(maxp * page)[None, None, None, :]
    scores = jnp.where(kpos < lengths[:, None, None, None], scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v)
    return out.reshape(b, h, d).astype(q.dtype)


def paged_attention_verify_ref(q, k_pages, v_pages, table, pos):
    """q: (B, Sq, H, D); k_pages, v_pages: (P, page, Hkv, D); table:
    (B, maxp) i32; pos: (B,) i32 -> (B, Sq, H, D), fp32 math.

    The k-position verify oracle: query row ``r`` sits at cache position
    ``pos + r`` and attends causally up to it (``kpos <= pos + r``) — the
    same contract ``models.layers.attention_verify_paged``'s XLA gather path
    implements, and row 0 degenerates to ``paged_attention_ref`` at
    ``lengths = pos + 1``."""
    b, sq, h, d = q.shape
    page = k_pages.shape[1]
    maxp = table.shape[1]
    hk = k_pages.shape[2]
    g = h // hk

    k = k_pages[table].reshape(b, maxp * page, hk, d).astype(jnp.float32)
    v = v_pages[table].reshape(b, maxp * page, hk, d).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, sq, hk, g, d)

    scores = jnp.einsum("bskgd,btkd->bkgst", qf, k) / (d ** 0.5)
    kpos = jnp.arange(maxp * page)[None, None, None, None, :]
    bound = (pos[:, None] + jnp.arange(sq)[None, :])[:, None, None, :, None]
    scores = jnp.where(kpos <= bound, scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, sq, h, d).astype(q.dtype)
