from .ops import paged_attention, paged_attention_ref

__all__ = ["paged_attention", "paged_attention_ref"]
