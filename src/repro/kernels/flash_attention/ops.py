"""jit'd public wrapper: picks the Pallas kernel on TPU, interpret mode elsewhere."""
from __future__ import annotations

import jax

from .kernel import flash_attention as _kernel_call
from .ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bkv: int = 128, interpret: bool | None = None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D). interpret=None -> auto (True off-TPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _kernel_call(q, k, v, bq=bq, bkv=bkv, causal=causal,
                        interpret=interpret)


__all__ = ["flash_attention", "attention_ref"]
