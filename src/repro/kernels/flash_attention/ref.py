"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D), fp32 math."""
    b, h, s, d = q.shape
    hk = k.shape[1]
    g = h // hk
    qf = q.astype(jnp.float32).reshape(b, hk, g, s, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qf, kf) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - scores.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)
