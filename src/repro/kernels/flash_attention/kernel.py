"""Pallas TPU flash attention: blocked causal GQA attention with online softmax.

Tiling (VMEM): grid = (B, H, S/bq, S/bkv) with the KV dimension innermost and
*sequential* — the (m, l, acc) running state lives in VMEM scratch and persists
across KV steps, exactly the TPU-native adaptation of the GPU flash algorithm
(the MXU consumes (bq, d) x (d, bkv) tiles; no (S, S) tensor ever exists in HBM).
Fully-masked causal blocks are skipped structurally (pl.when), so the causal
speedup is real compute skipped, not masked-and-wasted.

Layouts: q (B, H, S, D); k, v (B, Hkv, S, D); GQA maps q-head h -> kv-head
h // (H // Hkv) in the BlockSpec index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            *, bq: int, bkv: int, nkv: int, causal: bool, groups: int):
    del groups
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (sequential innermost)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # causal structural skip: the whole kv block is in the future
    live = (j * bkv <= i * bq + (bq - 1)) if causal else True

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (1.0 / (q.shape[-1] ** 0.5))
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] \
            + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bkv", "causal", "interpret"))
def flash_attention(q, k, v, *, bq: int = 128, bkv: int = 128,
                    causal: bool = True, interpret: bool = True):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    hk = k.shape[1]
    groups = h // hk
    bq = min(bq, s)
    bkv = min(bkv, s)
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    nq, nkv = s // bq, s // bkv

    kernel = functools.partial(_kernel, bq=bq, bkv=bkv, nkv=nkv,
                               causal=causal, groups=groups)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, i, j, g=groups: (b_, h_ // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, d),
                         lambda b_, h_, i, j, g=groups: (b_, h_ // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running sum l
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
