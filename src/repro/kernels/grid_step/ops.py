"""jit'd public wrapper for the grid stencil kernel."""
from __future__ import annotations

import jax

from .kernel import grid_step as _kernel_call
from .ref import grid_step_ref


def grid_step(labels, cond, *, band: int = 8, interpret: bool | None = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _kernel_call(labels, cond, band=band, interpret=interpret)


__all__ = ["grid_step", "grid_step_ref"]
