from .ops import grid_step, grid_step_ref  # noqa: F401
