"""Pallas TPU stencil for the blackboard max-diffusion step (the paper's hot op).

One synchronous step of ``label := max(label, 4-neighbour labels)`` within a
conductor mask — the propagation/fixpoint operation the VLSI extractor's observer
(and a batched variant of the propagator agents) applies per cycle.

Tiling: grid over row bands; each step reads its (band, W) block plus the
neighbouring bands through *three* BlockSpecs onto the same array with shifted
(clamped) index maps — the Pallas TPU idiom for halo exchange without overlapping
block support. Edge duplication from clamping is masked off with program-id
predicates. W stays whole per block (layout: rows are the tiled dim, the lane dim
stays dense/128-aligned for the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(lab_prev_ref, lab_cur_ref, lab_next_ref,
            cond_prev_ref, cond_cur_ref, cond_next_ref, o_ref, *, nb: int):
    i = pl.program_id(0)
    lab = lab_cur_ref[...]
    cond = cond_cur_ref[...] > 0
    band, w = lab.shape

    # in-band 4-neighbour shifts (zeros roll in at band edges, fixed up below)
    up = jnp.pad(lab[1:], ((0, 1), (0, 0)))
    down = jnp.pad(lab[:-1], ((1, 0), (0, 0)))
    left = jnp.pad(lab[:, 1:], ((0, 0), (0, 1)))
    right = jnp.pad(lab[:, :-1], ((0, 0), (1, 0)))
    cup = jnp.pad(cond_cur_ref[...][1:] > 0, ((0, 1), (0, 0)))
    cdown = jnp.pad(cond_cur_ref[...][:-1] > 0, ((1, 0), (0, 0)))
    cleft = jnp.pad(cond_cur_ref[...][:, 1:] > 0, ((0, 0), (0, 1)))
    cright = jnp.pad(cond_cur_ref[...][:, :-1] > 0, ((0, 0), (1, 0)))

    # halo rows from the neighbouring bands (masked at the outer edges, where the
    # clamped index maps would alias the current band)
    first_of_next = jnp.where(i < nb - 1, lab_next_ref[0], 0)
    cfirst_of_next = jnp.where(i < nb - 1, cond_next_ref[0] > 0, False)
    last_of_prev = jnp.where(i > 0, lab_prev_ref[band - 1], 0)
    clast_of_prev = jnp.where(i > 0, cond_prev_ref[band - 1] > 0, False)
    up = up.at[band - 1].set(first_of_next)
    cup = cup.at[band - 1].set(cfirst_of_next)
    down = down.at[0].set(last_of_prev)
    cdown = cdown.at[0].set(clast_of_prev)

    out = lab
    for nb_lab, nb_cond in ((up, cup), (down, cdown), (left, cleft),
                            (right, cright)):
        out = jnp.maximum(out, jnp.where(nb_cond & cond, nb_lab, 0))
    o_ref[...] = jnp.where(cond, out, lab)


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def grid_step(labels, cond, *, band: int = 8, interpret: bool = True):
    """labels, cond: (H, W) int32 -> (H, W) one masked max-diffusion step."""
    h, w = labels.shape
    band = min(band, h)
    while h % band:
        band -= 1
    nb = h // band

    kernel = functools.partial(_kernel, nb=nb)
    prev_map = lambda i: (jnp.maximum(i - 1, 0), 0)
    cur_map = lambda i: (i, 0)
    next_map = lambda i: (jnp.minimum(i + 1, nb - 1), 0)
    spec = lambda m: pl.BlockSpec((band, w), m)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[spec(prev_map), spec(cur_map), spec(next_map),
                  spec(prev_map), spec(cur_map), spec(next_map)],
        out_specs=spec(cur_map),
        out_shape=jax.ShapeDtypeStruct((h, w), labels.dtype),
        interpret=interpret,
    )(labels, labels, labels, cond, cond, cond)
