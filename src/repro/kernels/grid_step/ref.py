"""Pure-jnp oracle for the blackboard max-diffusion stencil."""
from __future__ import annotations

import jax.numpy as jnp


def grid_step_ref(labels, cond):
    """One synchronous step of label := max over 4-neighbours within cond."""
    c = cond > 0
    out = labels
    for dr, dc in ((-1, 0), (1, 0), (0, -1), (0, 1)):
        sh = jnp.roll(labels, (dr, dc), (0, 1))
        sc = jnp.roll(c, (dr, dc), (0, 1))
        # roll wrap: zero out the wrapped row/col
        if dr == -1:
            sh, sc = sh.at[-1].set(0), sc.at[-1].set(False)
        if dr == 1:
            sh, sc = sh.at[0].set(0), sc.at[0].set(False)
        if dc == -1:
            sh, sc = sh.at[:, -1].set(0), sc.at[:, -1].set(False)
        if dc == 1:
            sh, sc = sh.at[:, 0].set(0), sc.at[:, 0].set(False)
        out = jnp.maximum(out, jnp.where(sc & c, sh, 0))
    return jnp.where(c, out, labels)
