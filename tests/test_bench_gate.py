"""The bench-regression gate's rules, exercised on synthetic result trees:
parity bits are exact (no tolerance), per-section checks must be green, and
the capacity metrics (admission depth, pinned-hit rate) must not regress vs
the baseline — a missing baseline section skips with a note so the PR that
introduces a section can also introduce its baseline."""
from benchmarks.regression_gate import gate

BASELINE = {
    "pinning": {"summary": {"pinned_hit_rate": 0.5}},
    "preemption": {"summary": {"preempt_concurrency_hw": 4.0}},
    "routing": {"summary": {"affinity_hit_rate": 0.6}},
    "failover": {"summary": {"immune_goodput": 0.9}},
    "durability": {"summary": {"poweroff_goodput": 0.9}},
    "spec_decode": {"summary": {"spec_accept_rate": 0.5}},
}


def _new(hit=0.5, depth=4.0, parity=True, check=True, affinity=0.6,
         goodput=0.9, off_goodput=0.9, accept=0.5):
    return {
        "pinning": {"summary": {
            "pinned_hit_rate": hit,
            "pin_parity_exact": parity,
            "checks": {"pin_parity_exact": parity, "some_bar": check},
        }},
        "preemption": {"summary": {
            "preempt_concurrency_hw": depth,
            "preempt_parity_exact": True,
        }},
        "routing": {"summary": {
            "affinity_hit_rate": affinity,
            "routing_parity_exact": True,
        }},
        "failover": {"summary": {
            "immune_goodput": goodput,
            "failover_parity_exact": True,
        }},
        "durability": {"summary": {
            "poweroff_goodput": off_goodput,
            "durability_parity_exact": True,
        }},
        "spec_decode": {"summary": {
            "spec_accept_rate": accept,
            "spec_parity_exact": True,
        }},
    }


class TestGate:
    def test_clean_run_passes(self):
        assert gate(_new(), BASELINE) == []

    def test_improvement_passes(self):
        assert gate(_new(hit=0.9, depth=6.0), BASELINE) == []

    def test_parity_bit_is_exact(self):
        fails = gate(_new(parity=False), BASELINE)
        assert any("parity" in f for f in fails)

    def test_failed_check_fails(self):
        assert any("some_bar" in f for f in gate(_new(check=False), BASELINE))

    def test_depth_regression_fails(self):
        assert any("preempt_concurrency_hw" in f
                   for f in gate(_new(depth=3.0), BASELINE))

    def test_hit_rate_within_epsilon_passes(self):
        assert gate(_new(hit=0.495), BASELINE) == []

    def test_hit_rate_regression_fails(self):
        assert any("pinned_hit_rate" in f
                   for f in gate(_new(hit=0.3), BASELINE))

    def test_affinity_regression_fails(self):
        assert any("affinity_hit_rate" in f
                   for f in gate(_new(affinity=0.2), BASELINE))

    def test_failover_goodput_regression_fails(self):
        assert any("immune_goodput" in f
                   for f in gate(_new(goodput=0.5), BASELINE))

    def test_poweroff_goodput_regression_fails(self):
        assert any("poweroff_goodput" in f
                   for f in gate(_new(off_goodput=0.5), BASELINE))

    def test_accept_rate_regression_fails(self):
        assert any("spec_accept_rate" in f
                   for f in gate(_new(accept=0.3), BASELINE))

    def test_missing_baseline_section_skips(self):
        assert gate(_new(), {}) == []

    def test_missing_new_metric_fails(self):
        new = _new()
        del new["preemption"]["summary"]["preempt_concurrency_hw"]
        assert any("missing" in f for f in gate(new, BASELINE))
