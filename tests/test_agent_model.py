"""The generic Hewes MIMD framework: dominance writes, presence channels, drivers."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import agent_model as am

jax.config.update("jax_platform_name", "cpu")


def _count_model():
    """Two characteristics: type 0 writes its id+1 at its cell and converts to type 1
    when it reads a value above its own (dominance loss); type 1 idles."""

    def writer(ctx):
        w = jnp.zeros((2, 4), jnp.int32)
        w = w.at[0].set(jnp.stack([jnp.int32(0), ctx.pos[0], ctx.pos[1],
                                   ctx.agent_id + 1]))
        dominated = ctx.patch[0, 1, 1] > ctx.agent_id + 1
        return am.AgentUpdate(w, ctx.state,
                              jnp.where(dominated, 1, 0).astype(jnp.int32),
                              jnp.float32(1.0), ctx.pos)

    def idler(ctx):
        return am.AgentUpdate(jnp.zeros((2, 4), jnp.int32), ctx.state,
                              jnp.int32(1), jnp.float32(1.0), ctx.pos)

    return am.AgentModel([writer, idler], num_channels=4, state_size=2,
                         writes_cap=2, presence_channel=2)


def test_scatter_max_dominance_and_transitions():
    model = _count_model()
    grid = jnp.zeros((4, 8, 8), jnp.int32)
    # two agents on the same cell: the higher id must win, the loser converts
    agents = am.Agents(type_id=jnp.zeros(2, jnp.int32),
                       prev_type=jnp.full(2, -1, jnp.int32),
                       pos=jnp.asarray([[3, 3], [3, 3]], jnp.int32),
                       state=jnp.zeros((2, 2), jnp.int32))
    key = jax.random.PRNGKey(0)
    g, a = model.step(grid, agents, key, jnp.int32(0))
    assert int(g[0, 3, 3]) == 2              # max(id 0 + 1, id 1 + 1)
    g, a = model.step(g, a, key, jnp.int32(1))
    assert int(a.type_id[0]) == 1            # agent 0 read 2 > 1 -> dominated
    assert int(a.type_id[1]) == 0            # agent 1 saw its own value
    assert int(a.prev_type[0]) == 0          # ancestor recorded


def test_presence_channels_rebuilt_each_step():
    model = _count_model()
    grid = jnp.zeros((4, 8, 8), jnp.int32)
    agents = am.Agents(type_id=jnp.asarray([0, 1], jnp.int32),
                       prev_type=jnp.full(2, -1, jnp.int32),
                       pos=jnp.asarray([[2, 2], [5, 5]], jnp.int32),
                       state=jnp.zeros((2, 2), jnp.int32))
    g, a = model.step(grid, agents, jax.random.PRNGKey(0), jnp.int32(0))
    assert int(g[2, 2, 2]) == 1 and int(g[3, 5, 5]) == 1
    # after the type-1 agent stays put, presence follows the *current* population
    g, a = model.step(g, a, jax.random.PRNGKey(1), jnp.int32(1))
    assert int(g[2 + int(a.type_id[0]), 2, 2]) == 1


def test_run_scan_freezes_after_done():
    model = _count_model()
    grid = jnp.zeros((4, 8, 8), jnp.int32)
    agents = am.uniform_random_agents(jax.random.PRNGKey(2), 4, 8, 8, 2)
    done_fn = lambda g: (g[0] > 0).sum() >= 1
    g, a, steps, pops = model.run_scan(grid, agents, jax.random.PRNGKey(3), 10,
                                       done_fn=done_fn, record=True)
    assert int(steps) <= 2
    assert pops.shape == (10, 2)


def test_positions_stay_interior():
    model = _count_model()
    grid = jnp.zeros((4, 6, 6), jnp.int32)
    agents = am.uniform_random_agents(jax.random.PRNGKey(4), 16, 6, 6, 2)
    g, a = grid, agents
    for t in range(5):
        g, a = model.step(g, a, jax.random.fold_in(jax.random.PRNGKey(5), t),
                          jnp.int32(t))
    assert bool(jnp.all((a.pos >= 1) & (a.pos <= 4)))
