"""Multi-replica placement router: policy behavior + placement invariance.

The load-bearing property is the engine parity oracle lifted one level:
placement decides *where* a request runs, never *what* it computes, so
per-request tokens are bitwise identical across router policies and replica
counts — including requests preempted and replayed on one replica — and all
of them match one-shot ``decode.generate``. The policy tests pin the three
immune placement signals (prefix affinity, anergy draining, least remembered
cost) and the rr/jsq baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import decode, traces
from repro.serve import engine as eng_mod
from repro.serve import router as rt_mod
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.faults import FaultInjector, FaultPlan

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    return cfg, model.init_params(jax.random.PRNGKey(0), cfg)


def _ecfg(**kw):
    base = dict(num_slots=2, max_cache=64, page_size=16, prefill_chunk=8,
                policy="immune", num_classes=3, latency_budget=64.0,
                pin_pages=4)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _engines(params, cfg, n, **kw):
    return [eng_mod.Engine(params, cfg, _ecfg(**kw)) for _ in range(n)]


def _req(rid, rclass=0, plen=8, steps=4, tokens=None):
    if tokens is None:
        tokens = np.arange(plen, dtype=np.int32) + rid
    return ServeRequest(rid=rid, tokens=np.asarray(tokens, np.int32),
                        params=SamplingParams(max_new_tokens=steps),
                        rclass=rclass)


def _fleet(cfg, **kw):
    base = dict(tenants=3, num_requests=9, prefix_len=32, suffix_lens=(4,),
                decode_lens=(6,), hot_frac=0.5, burst_every=4, burst_size=3,
                seed=0)
    base.update(kw)
    return traces.fleet_trace(cfg, **base)


def _oracle(params, cfg, reqs, max_cache):
    out = {}
    for r in reqs:
        toks, _ = decode.generate(params, cfg, r.prompts(),
                                  max_cache=max_cache,
                                  steps=r.max_new_tokens)
        out[r.rid] = [int(t) for t in np.asarray(toks[0])]
    return out


class TestPlacementPolicies:
    def test_round_robin_cycles(self, dense):
        cfg, params = dense
        router = rt_mod.Router(_engines(params, cfg, 3),
                               rt_mod.RouterConfig(policy="rr"))
        assert [router._place(_req(i)) for i in range(5)] == [0, 1, 2, 0, 1]

    def test_jsq_picks_least_occupied(self, dense):
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        engines[0].submit(_req(0))
        engines[0].submit(_req(1))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="jsq"))
        assert router._place(_req(2)) == 1
        # ties break on the lowest index, deterministically
        engines[1].submit(_req(3))
        engines[1].submit(_req(4))
        assert router._place(_req(5)) == 0

    def test_affinity_routes_to_resident_replica(self, dense):
        """A replica that already holds a prompt's page chains (pinned after
        its donor drained) wins placement over an emptier replica — and the
        hit is counted with its resident token length."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        prefix = np.arange(32, dtype=np.int32)
        donor = _req(0, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([7, 7, 7, 7], np.int32)]))
        engines[1].run([donor], max_ticks=100)          # chains pin on replica 1
        assert engines[1].alloc.pages_pinned > 0
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        follower = _req(1, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([9, 9, 9, 9], np.int32)]))
        assert router._place(follower) == 1
        assert router.affinity_hits == 1
        assert router.affinity_tokens >= 32

    def test_affinity_forfeited_by_backlogged_replica(self, dense):
        """Anti-convoy: a replica whose backlog exceeds affinity_queue_cap *
        num_slots loses its affinity claim and the load model places instead."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        prefix = np.arange(32, dtype=np.int32)
        donor = _req(0, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([7, 7, 7, 7], np.int32)]))
        engines[1].run([donor], max_ticks=100)
        for i in range(5):                   # backlog replica 1 past 2*2 slots
            engines[1].submit(_req(10 + i, rclass=1))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        follower = _req(1, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([9, 9, 9, 9], np.int32)]))
        assert router._place(follower) == 0
        assert router.affinity_hits == 0

    def test_drains_anergic_replica(self, dense):
        """A replica anergic for the request's class takes no new placements
        of it; with every replica anergic the least-anergic one still serves
        (counted as drain overflow)."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        lvl = np.zeros(3, np.float32)
        lvl[0] = 0.9
        engines[0].admission.anergy = engines[0].admission.anergy._replace(
            level=jnp.asarray(lvl))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        assert router._place(_req(0, rclass=0)) == 1
        assert router.drain_skips == 1
        # other classes still place by load (engine 0 not drained for them)
        assert router._place(_req(1, rclass=1)) == 0
        engines[1].admission.anergy = engines[1].admission.anergy._replace(
            level=jnp.asarray(lvl * 0.8))    # anergic too, but less so
        assert router._place(_req(2, rclass=0)) == 1
        assert router.drain_overflow == 1

    def test_least_remembered_cost_placement(self, dense):
        """With no affinity claim, placement prices each replica's backlog at
        its classes' cost EMAs: one queued request of a historically expensive
        class outweighs one of a cheap class — which occupancy-only jsq
        cannot see."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        for _ in range(10):
            engines[0].admission.observe_completion(0, cost=40.0, latency=5.0)
        engines[0].submit(_req(0, rclass=0))   # priced ~40
        engines[1].submit(_req(1, rclass=1))   # cold class: cost floor
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        assert router._place(_req(2, rclass=2)) == 1
        jsq = rt_mod.Router(engines, rt_mod.RouterConfig(policy="jsq"))
        assert jsq._place(_req(3, rclass=2)) == 0   # occupancy tie -> index


class TestPlacementInvariance:
    """Same request set -> bitwise-identical per-request tokens under every
    (policy, replica-count) pair, all matching the one-shot oracle."""

    def test_tokens_identical_across_policies_and_replicas(self, dense):
        cfg, params = dense
        oracle = _oracle(params, cfg, _fleet(cfg), 64)
        for policy in rt_mod.POLICIES:
            for n in (1, 2, 3):
                # fresh trace per run: requests are mutated by serving
                router = rt_mod.Router(
                    _engines(params, cfg, n),
                    rt_mod.RouterConfig(policy=policy))
                stats = router.run(_fleet(cfg), max_ticks=500)
                assert stats["completed"] == 9 and stats["shed"] == 0, \
                    (policy, n)
                for r in router.completed:
                    assert r.out_tokens == oracle[r.rid], \
                        f"rid {r.rid} diverged under {policy} x{n}"
                if policy == "immune":
                    assert stats["affinity_hits"] > 0, (policy, n)

    def test_invariant_across_preemption(self, dense):
        """Replicas with page pools tiny enough to preempt at low replica
        counts: a preempted-then-replayed request still emits oracle tokens,
        and adding replicas (no preemption) changes nothing."""
        cfg, params = dense
        mk = lambda: _fleet(cfg, tenants=2, num_requests=4, prefix_len=8,
                            suffix_lens=(2,), decode_lens=(8,),
                            burst_every=2, burst_size=4)
        oracle = _oracle(params, cfg, mk(), 32)
        preempted = {}
        for n in (1, 2):
            router = rt_mod.Router(
                _engines(params, cfg, n, max_cache=32, num_pages=3,
                         prefill_chunk=0, pin_pages=0, num_classes=2),
                rt_mod.RouterConfig(policy="immune"))
            stats = router.run(mk(), max_ticks=300)
            assert stats["completed"] == 4 and stats["shed"] == 0, n
            preempted[n] = stats["preemptions"]
            for r in router.completed:
                assert r.out_tokens == oracle[r.rid], \
                    f"rid {r.rid} diverged at {n} replicas " \
                    f"({stats['preemptions']} preemptions)"
        assert preempted[1] >= 1, \
            "the tiny single-replica pool should have preempted"


class TestRouterHarness:
    def test_rejects_bad_policy_and_empty_fleet(self, dense):
        cfg, params = dense
        with pytest.raises(ValueError, match="policy"):
            rt_mod.Router(_engines(params, cfg, 1),
                          rt_mod.RouterConfig(policy="maxflow"))
        with pytest.raises(ValueError, match="at least one"):
            rt_mod.Router([], rt_mod.RouterConfig())

    def test_stats_aggregate_fleet(self, dense):
        cfg, params = dense
        router = rt_mod.Router(_engines(params, cfg, 2),
                               rt_mod.RouterConfig(policy="rr"))
        stats = router.run(_fleet(cfg, num_requests=6), max_ticks=300)
        assert stats["router"] == "rr" and stats["replicas"] == 2
        assert stats["completed"] == 6 and stats["unserved"] == 0
        assert sum(stats["placements"]) == 6
        assert stats["placements"] == [3, 3]       # rr splits evenly
        assert len(stats["per_replica"]) == 2
        assert stats["tokens"] == sum(
            p["tokens"] for p in stats["per_replica"])
        assert stats["goodput"] == 1.0
        assert np.isfinite(stats["p99_latency"])

    def test_stats_safe_on_fresh_router_and_idle_replica(self, dense):
        """stats() must not divide by zero or crash on a router that has
        served nothing, nor on a fleet where one replica completed zero
        requests (e.g. it joined late or all its traffic went elsewhere)."""
        cfg, params = dense
        router = rt_mod.Router(_engines(params, cfg, 2))
        s = router.stats()
        assert s["completed"] == 0 and s["goodput"] == 0.0
        assert s["p50_latency"] == float("inf")
        assert s["placement_imbalance"] == 0.0 and s["recovery_ticks"] == 0
        # 3 replicas, 2 requests under rr: replica 2 completes nothing
        router = rt_mod.Router(_engines(params, cfg, 3),
                               rt_mod.RouterConfig(policy="rr"))
        s = router.run(_fleet(cfg, num_requests=2), max_ticks=300)
        assert s["placements"][2] == 0
        assert s["per_replica"][2]["completed"] == 0
        assert s["completed"] == 2 and np.isfinite(s["p99_latency"])


class TestHealthMachine:
    """healthy -> suspect -> dead transitions from missed step deadlines,
    and the two failover regressions: a re-placed request keeps its original
    arrival (victim scoring must not see it as the latest arrival) and its
    original submit_time (wall latency spans crash + replay)."""

    def test_stall_flaps_suspect_then_recovers(self, dense):
        cfg, params = dense
        router = rt_mod.Router(
            _engines(params, cfg, 2), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("stall@1+3:r0")))
        seen = []
        for _ in range(6):
            router.step()
            seen.append(router.health[0])
        assert seen == [rt_mod.HEALTHY, rt_mod.HEALTHY, rt_mod.SUSPECT,
                        rt_mod.SUSPECT, rt_mod.HEALTHY, rt_mod.HEALTHY]
        assert router.deaths == 0

    def test_suspect_replica_takes_no_new_placements(self, dense):
        cfg, params = dense
        router = rt_mod.Router(
            _engines(params, cfg, 2), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("stall@1+4:r0")))
        for _ in range(3):
            router.step()              # replica 0 is now suspect
        assert router.health[0] == rt_mod.SUSPECT
        assert router._eligible() == [1]
        before = router.placements.copy()
        for rid in range(4):
            router.submit(_req(rid))
        router.step()
        placed = router.placements - before
        assert placed[0] == 0 and placed[1] == 4

    def test_crash_walks_to_dead_and_stays_fenced(self, dense):
        cfg, params = dense
        router = rt_mod.Router(
            _engines(params, cfg, 2), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("crash@1:r0")))
        while router.health[0] != rt_mod.DEAD and router.tick < 20:
            router.step()
        # last stepped at tick 0; missed >= dead_after(6) first at tick 6
        assert router.death_ticks == [6]
        old_tick = router.engines[0].tick
        router.step()
        assert router.health[0] == rt_mod.DEAD       # never un-declared
        assert router.engines[0].tick == old_tick    # fenced: no more steps

    def test_queue_holds_when_no_replica_is_healthy(self, dense):
        cfg, params = dense
        router = rt_mod.Router(
            _engines(params, cfg, 1), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("crash@1:r0")))
        reqs = _fleet(cfg, num_requests=4)
        s = router.run(reqs, max_ticks=40)
        assert router.health == [rt_mod.DEAD]
        assert s["unserved"] > 0                     # held, not dropped
        assert s["completed"] + s["shed"] + s["rejected"] + s["failed"] \
            + s["unserved"] == len(reqs)

    def test_replaced_request_keeps_arrival_for_victim_scoring(self, dense):
        """Satellite regression: failover re-placement must not refresh
        ``arrival`` — the victim scorer's latest-arrival tiebreak would then
        evict the recovering request first, starving exactly the work the
        fleet just promised to save."""
        cfg, params = dense
        reqs = _fleet(cfg, num_requests=9)
        arrivals = {r.rid: r.arrival for r in reqs}
        router = rt_mod.Router(
            _engines(params, cfg, 3), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("crash@4:r0")))
        router.run(reqs)
        assert router.replaced_rids
        for r in reqs:
            assert r.arrival == arrivals[r.rid], r.rid
        # and the scorer itself: same class, same progress -> the later
        # arrival is the preferred victim, so keeping the original arrival
        # shields the recovering request
        eng = _engines(params, cfg, 1)[0]
        recovering, fresh = _req(100), _req(101)
        recovering.arrival, fresh.arrival = 0, 10
        assert eng._victim_score(fresh) > eng._victim_score(recovering)

    def test_replaced_request_keeps_submit_time_wall_clock(self, dense):
        """Satellite regression: wall-clock latency must span crash + replay.
        ``Engine.submit`` stamps ``submit_time`` only on first submission, so
        re-placement on a survivor keeps the original clock."""
        cfg, params = dense
        e0, e1 = _engines(params, cfg, 2)
        req = _req(0)
        e0.submit(req)
        t0 = req.submit_time
        assert t0 >= 0
        e1.submit(req)                     # the failover re-submission path
        assert req.submit_time == t0

    def test_retry_backoff_delays_second_replacement(self, dense):
        """First re-placement is immediate; a request evacuated twice waits
        ``retry_backoff`` ticks in the backoff heap before re-queueing."""
        cfg, params = dense
        router = rt_mod.Router(
            _engines(params, cfg, 2),
            rt_mod.RouterConfig(policy="rr", max_retries=3, retry_backoff=2))
        req = _req(0, steps=4)
        req.retries = 1                    # already evacuated once elsewhere
        router.engines[0].submit(req)
        router.tick = 5
        router._declare_dead(0)
        assert not router.queue            # parked in the backoff heap
        assert router._retry and router._retry[0][0] == 5 + 1 + 2
        for _ in range(4):
            router.step()
        assert not router._retry           # released once ready_tick passed
