"""Multi-replica placement router: policy behavior + placement invariance.

The load-bearing property is the engine parity oracle lifted one level:
placement decides *where* a request runs, never *what* it computes, so
per-request tokens are bitwise identical across router policies and replica
counts — including requests preempted and replayed on one replica — and all
of them match one-shot ``decode.generate``. The policy tests pin the three
immune placement signals (prefix affinity, anergy draining, least remembered
cost) and the rr/jsq baselines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import decode, traces
from repro.serve import engine as eng_mod
from repro.serve import router as rt_mod
from repro.serve.api import SamplingParams, ServeRequest

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    return cfg, model.init_params(jax.random.PRNGKey(0), cfg)


def _ecfg(**kw):
    base = dict(num_slots=2, max_cache=64, page_size=16, prefill_chunk=8,
                policy="immune", num_classes=3, latency_budget=64.0,
                pin_pages=4)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _engines(params, cfg, n, **kw):
    return [eng_mod.Engine(params, cfg, _ecfg(**kw)) for _ in range(n)]


def _req(rid, rclass=0, plen=8, steps=4, tokens=None):
    if tokens is None:
        tokens = np.arange(plen, dtype=np.int32) + rid
    return ServeRequest(rid=rid, tokens=np.asarray(tokens, np.int32),
                        params=SamplingParams(max_new_tokens=steps),
                        rclass=rclass)


def _fleet(cfg, **kw):
    base = dict(tenants=3, num_requests=9, prefix_len=32, suffix_lens=(4,),
                decode_lens=(6,), hot_frac=0.5, burst_every=4, burst_size=3,
                seed=0)
    base.update(kw)
    return traces.fleet_trace(cfg, **base)


def _oracle(params, cfg, reqs, max_cache):
    out = {}
    for r in reqs:
        toks, _ = decode.generate(params, cfg, r.prompts(),
                                  max_cache=max_cache,
                                  steps=r.max_new_tokens)
        out[r.rid] = [int(t) for t in np.asarray(toks[0])]
    return out


class TestPlacementPolicies:
    def test_round_robin_cycles(self, dense):
        cfg, params = dense
        router = rt_mod.Router(_engines(params, cfg, 3),
                               rt_mod.RouterConfig(policy="rr"))
        assert [router._place(_req(i)) for i in range(5)] == [0, 1, 2, 0, 1]

    def test_jsq_picks_least_occupied(self, dense):
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        engines[0].submit(_req(0))
        engines[0].submit(_req(1))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="jsq"))
        assert router._place(_req(2)) == 1
        # ties break on the lowest index, deterministically
        engines[1].submit(_req(3))
        engines[1].submit(_req(4))
        assert router._place(_req(5)) == 0

    def test_affinity_routes_to_resident_replica(self, dense):
        """A replica that already holds a prompt's page chains (pinned after
        its donor drained) wins placement over an emptier replica — and the
        hit is counted with its resident token length."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        prefix = np.arange(32, dtype=np.int32)
        donor = _req(0, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([7, 7, 7, 7], np.int32)]))
        engines[1].run([donor], max_ticks=100)          # chains pin on replica 1
        assert engines[1].alloc.pages_pinned > 0
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        follower = _req(1, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([9, 9, 9, 9], np.int32)]))
        assert router._place(follower) == 1
        assert router.affinity_hits == 1
        assert router.affinity_tokens >= 32

    def test_affinity_forfeited_by_backlogged_replica(self, dense):
        """Anti-convoy: a replica whose backlog exceeds affinity_queue_cap *
        num_slots loses its affinity claim and the load model places instead."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        prefix = np.arange(32, dtype=np.int32)
        donor = _req(0, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([7, 7, 7, 7], np.int32)]))
        engines[1].run([donor], max_ticks=100)
        for i in range(5):                   # backlog replica 1 past 2*2 slots
            engines[1].submit(_req(10 + i, rclass=1))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        follower = _req(1, rclass=0, tokens=np.concatenate(
            [prefix, np.asarray([9, 9, 9, 9], np.int32)]))
        assert router._place(follower) == 0
        assert router.affinity_hits == 0

    def test_drains_anergic_replica(self, dense):
        """A replica anergic for the request's class takes no new placements
        of it; with every replica anergic the least-anergic one still serves
        (counted as drain overflow)."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        lvl = np.zeros(3, np.float32)
        lvl[0] = 0.9
        engines[0].admission.anergy = engines[0].admission.anergy._replace(
            level=jnp.asarray(lvl))
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        assert router._place(_req(0, rclass=0)) == 1
        assert router.drain_skips == 1
        # other classes still place by load (engine 0 not drained for them)
        assert router._place(_req(1, rclass=1)) == 0
        engines[1].admission.anergy = engines[1].admission.anergy._replace(
            level=jnp.asarray(lvl * 0.8))    # anergic too, but less so
        assert router._place(_req(2, rclass=0)) == 1
        assert router.drain_overflow == 1

    def test_least_remembered_cost_placement(self, dense):
        """With no affinity claim, placement prices each replica's backlog at
        its classes' cost EMAs: one queued request of a historically expensive
        class outweighs one of a cheap class — which occupancy-only jsq
        cannot see."""
        cfg, params = dense
        engines = _engines(params, cfg, 2)
        for _ in range(10):
            engines[0].admission.observe_completion(0, cost=40.0, latency=5.0)
        engines[0].submit(_req(0, rclass=0))   # priced ~40
        engines[1].submit(_req(1, rclass=1))   # cold class: cost floor
        router = rt_mod.Router(engines, rt_mod.RouterConfig(policy="immune"))
        assert router._place(_req(2, rclass=2)) == 1
        jsq = rt_mod.Router(engines, rt_mod.RouterConfig(policy="jsq"))
        assert jsq._place(_req(3, rclass=2)) == 0   # occupancy tie -> index


class TestPlacementInvariance:
    """Same request set -> bitwise-identical per-request tokens under every
    (policy, replica-count) pair, all matching the one-shot oracle."""

    def test_tokens_identical_across_policies_and_replicas(self, dense):
        cfg, params = dense
        oracle = _oracle(params, cfg, _fleet(cfg), 64)
        for policy in rt_mod.POLICIES:
            for n in (1, 2, 3):
                # fresh trace per run: requests are mutated by serving
                router = rt_mod.Router(
                    _engines(params, cfg, n),
                    rt_mod.RouterConfig(policy=policy))
                stats = router.run(_fleet(cfg), max_ticks=500)
                assert stats["completed"] == 9 and stats["shed"] == 0, \
                    (policy, n)
                for r in router.completed:
                    assert r.out_tokens == oracle[r.rid], \
                        f"rid {r.rid} diverged under {policy} x{n}"
                if policy == "immune":
                    assert stats["affinity_hits"] > 0, (policy, n)

    def test_invariant_across_preemption(self, dense):
        """Replicas with page pools tiny enough to preempt at low replica
        counts: a preempted-then-replayed request still emits oracle tokens,
        and adding replicas (no preemption) changes nothing."""
        cfg, params = dense
        mk = lambda: _fleet(cfg, tenants=2, num_requests=4, prefix_len=8,
                            suffix_lens=(2,), decode_lens=(8,),
                            burst_every=2, burst_size=4)
        oracle = _oracle(params, cfg, mk(), 32)
        preempted = {}
        for n in (1, 2):
            router = rt_mod.Router(
                _engines(params, cfg, n, max_cache=32, num_pages=3,
                         prefill_chunk=0, pin_pages=0, num_classes=2),
                rt_mod.RouterConfig(policy="immune"))
            stats = router.run(mk(), max_ticks=300)
            assert stats["completed"] == 4 and stats["shed"] == 0, n
            preempted[n] = stats["preemptions"]
            for r in router.completed:
                assert r.out_tokens == oracle[r.rid], \
                    f"rid {r.rid} diverged at {n} replicas " \
                    f"({stats['preemptions']} preemptions)"
        assert preempted[1] >= 1, \
            "the tiny single-replica pool should have preempted"


class TestRouterHarness:
    def test_rejects_bad_policy_and_empty_fleet(self, dense):
        cfg, params = dense
        with pytest.raises(ValueError, match="policy"):
            rt_mod.Router(_engines(params, cfg, 1),
                          rt_mod.RouterConfig(policy="maxflow"))
        with pytest.raises(ValueError, match="at least one"):
            rt_mod.Router([], rt_mod.RouterConfig())

    def test_stats_aggregate_fleet(self, dense):
        cfg, params = dense
        router = rt_mod.Router(_engines(params, cfg, 2),
                               rt_mod.RouterConfig(policy="rr"))
        stats = router.run(_fleet(cfg, num_requests=6), max_ticks=300)
        assert stats["router"] == "rr" and stats["replicas"] == 2
        assert stats["completed"] == 6 and stats["unserved"] == 0
        assert sum(stats["placements"]) == 6
        assert stats["placements"] == [3, 3]       # rr splits evenly
        assert len(stats["per_replica"]) == 2
        assert stats["tokens"] == sum(
            p["tokens"] for p in stats["per_replica"])
        assert stats["goodput"] == 1.0
        assert np.isfinite(stats["p99_latency"])
