"""Paged KV allocator: unit behavior + churn invariants for the refcounted,
prefix-sharing design.

The allocator is pure host logic, so these tests run in microseconds; the
hypothesis case drives random admit/adopt/fork/grow/release sequences and
checks the layout invariants the device side silently relies on — above all
that the refcounts exactly mirror the block tables (sum of refcounts == live
block-table entries), that no page is ever freed while a slot still references
it, and that a CoW fork lands on a fresh page (never aliasing a still-shared
one). A violation of any of these would silently corrupt another request's KV,
which token-parity tests can only catch by luck.
"""
import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from repro.serve.paging import (NULL_PAGE, OutOfPages, PageAllocator,
                                pages_for)

SETTINGS = hypothesis.settings(deadline=None, max_examples=60)


def _alloc(num_pages=9, page_size=16, num_slots=3, maxp=4, share=True):
    return PageAllocator(num_pages, page_size, num_slots, maxp,
                         share_prefix=share)


def _toks(rng, n):
    return rng.integers(0, 256, size=n).astype(np.int32)


class TestPagesFor:
    def test_rounds_up(self):
        assert pages_for(1, 16) == 1
        assert pages_for(16, 16) == 1
        assert pages_for(17, 16) == 2
        assert pages_for(64, 16) == 4


class TestAllocFreeReuse:
    def test_alloc_free_reuse_cycle(self):
        a = _alloc()
        a.reserve(0, 3)
        a.ensure(0, 3)
        first = a.owned(0)
        assert len(first) == 3 and NULL_PAGE not in first
        assert a.pages_in_use == 3
        assert all(a.refcount(p) == 1 for p in first)
        a.release(0)
        assert a.pages_in_use == 0 and a.owned(0) == []
        # freed pages are reusable by another slot
        a.reserve(1, 4)
        a.ensure(1, 4)
        assert set(first) <= set(a.owned(1)) | set(a._free)
        assert a.high_water == 4

    def test_table_maps_logical_to_physical_in_order(self):
        a = _alloc()
        a.reserve(2, 2)
        a.ensure(2, 2)
        t = a.table()
        assert t.shape == (3, 4)
        assert list(t[2, :2]) == a.owned(2)
        assert (t[2, 2:] == NULL_PAGE).all()
        assert (t[:2] == NULL_PAGE).all()

    def test_release_returns_unused_reservation(self):
        """Early EOS: a slot that reserved 4 but only touched 1 page gives the
        other 3 promises back."""
        a = _alloc()
        a.reserve(0, 4)
        a.ensure(0, 1)
        assert a.available() == 8 - 1 - 3
        a.release(0)
        assert a.available() == 8 and a.pages_in_use == 0

    def test_fragmentation_churn_has_no_leak(self):
        """Interleaved alloc/free of mixed sizes: conservation holds and the
        full pool is reachable again after the churn."""
        a = _alloc(num_pages=17, num_slots=4, maxp=4)
        for round_ in range(50):
            slot = round_ % 4
            if a.owned(slot):
                a.release(slot)
            need = 1 + (round_ * 7) % 4
            if a.can_admit(need):
                a.reserve(slot, need)
                a.ensure(slot, need)
        for slot in range(4):
            a.release(slot)
        assert a.pages_in_use == 0 and a.available() == 16


class TestBackpressure:
    def test_out_of_pages_is_not_an_error(self):
        a = _alloc(num_pages=5, maxp=4)        # 4 usable
        a.reserve(0, 3)
        assert not a.can_admit(2)              # only 1 unpromised page left
        assert a.can_admit(1)
        a.release(0)
        assert a.can_admit(4)

    def test_reservation_guards_lazy_growth(self):
        a = _alloc(num_pages=5, maxp=4)
        a.reserve(0, 2)
        with pytest.raises(RuntimeError, match="reservation"):
            a.ensure(0, 3)                     # growing past the promise

    def test_max_pages_per_slot_is_enforced(self):
        a = _alloc(num_pages=17, maxp=2)
        assert not a.can_admit(3)
        a.reserve(0, 2)
        with pytest.raises(RuntimeError, match="max_pages_per_slot"):
            a.ensure(0, 3)


class TestPrefixIndex:
    """The sharing machinery: register -> match -> adopt -> fork -> free."""

    def test_match_full_pages_never_includes_last_prompt_token(self):
        """A prompt of exactly N full pages matches at most N-1 of them: the
        page holding the final prompt token is always recomputed (its logits
        seed decoding), so it is capped out of the match."""
        rng = np.random.default_rng(0)
        a = _alloc(num_pages=17, page_size=4, maxp=4)
        toks = _toks(rng, 16)                  # 4 full pages
        a.reserve(0, 4)
        a.ensure(0, 4)
        assert a.register_prefix(0, toks) == 4
        full, partial = a.match_prefix(toks)   # identical prompt
        assert full == a.owned(0)[:3]          # page 3 holds token 15 == last
        assert partial is not None
        assert partial == (a.owned(0)[3], 3)   # tokens 12..14 of page 3

    def test_match_walks_chain_and_stops_at_divergence(self):
        rng = np.random.default_rng(1)
        a = _alloc(num_pages=17, page_size=4, maxp=4)
        toks = _toks(rng, 16)
        a.reserve(0, 4)
        a.ensure(0, 4)
        a.register_prefix(0, toks)
        other = toks.copy()
        other[5] = (other[5] + 1) % 256        # diverge inside page 1
        full, partial = a.match_prefix(np.concatenate([other, _toks(rng, 4)]))
        assert full == a.owned(0)[:1]          # page 0 matches, page 1 doesn't
        assert partial == (a.owned(0)[1], 1)   # ...but its first token does

    def test_same_content_different_chain_position_does_not_match(self):
        """The index is keyed per page *chain*, not per page content: page P of
        one prompt must not satisfy page Q != P of another even if the 16
        tokens coincide."""
        a = _alloc(num_pages=17, page_size=4, maxp=4)
        block = np.asarray([7, 7, 7, 7], np.int32)
        toks = np.concatenate([block, block])  # pages 0 and 1 identical
        a.reserve(0, 2)
        a.ensure(0, 2)
        # both registrable: same content but different chain keys
        assert a.register_prefix(0, toks) == 2
        probe = np.concatenate([block + 1, block, np.zeros(2, np.int32)])
        full, partial = a.match_prefix(probe)
        assert full == [] and partial is None  # page-1 content at position 0: no

    def test_adopt_refcounts_and_free_on_zero(self):
        rng = np.random.default_rng(2)
        a = _alloc(num_pages=9, page_size=4, maxp=4)
        toks = _toks(rng, 9)
        a.reserve(0, 3)
        a.ensure(0, 3)
        a.register_prefix(0, toks)             # pages 0, 1 (9//4 = 2)
        full, _ = a.match_prefix(toks)
        assert full == a.owned(0)[:2]
        a.reserve(1, 1)
        a.adopt(1, full)
        assert [a.refcount(p) for p in full] == [2, 2]
        assert a.pages_in_use == 3             # shared pages counted once
        a.release(0)                           # donor retires first
        assert [a.refcount(p) for p in full] == [1, 1]
        assert a.match_prefix(toks)[0] == full  # still indexed: pages live
        a.release(1)
        assert a.pages_in_use == 0 and a.live_refs() == 0
        assert a.match_prefix(toks) == ([], None)  # free-on-zero unindexed

    def test_adopting_a_free_page_is_an_error(self):
        a = _alloc()
        a.reserve(0, 1)
        a.ensure(0, 1)
        page = a.owned(0)[0]
        a.release(0)
        a.reserve(1, 1)
        with pytest.raises(RuntimeError, match="not live"):
            a.adopt(1, [page])

    def test_cow_fork_moves_owner_off_shared_page(self):
        rng = np.random.default_rng(3)
        a = _alloc(num_pages=9, page_size=4, maxp=4)
        toks = _toks(rng, 9)
        a.reserve(0, 3)
        a.ensure(0, 3)
        a.register_prefix(0, toks)
        full, _ = a.match_prefix(toks)
        a.reserve(1, 2)                        # 1 private + 1 fork target
        a.adopt(1, full)
        shared = a.owned(1)[1]
        src, dst = a.cow_fork(1, 1)
        assert src == shared and dst != shared
        assert a.refcount(dst) == 1            # never aliases a shared page
        assert a.refcount(src) == 1            # donor keeps its copy
        assert a.owned(1)[1] == dst and a.owned(0)[1] == src
        assert a.match_prefix(toks)[0] == full  # index still points at src
        a.release(0)
        a.release(1)
        assert a.live_refs() == 0 and a.pages_in_use == 0

    def test_fork_draws_from_reservation(self):
        rng = np.random.default_rng(4)
        a = _alloc(num_pages=9, page_size=4, maxp=4)
        toks = _toks(rng, 9)
        a.reserve(0, 3)
        a.ensure(0, 3)
        a.register_prefix(0, toks)
        full, _ = a.match_prefix(toks)
        a.reserve(1, 0)                        # full-hit-only charge: no fork
        a.adopt(1, full)
        with pytest.raises(RuntimeError, match="reservation"):
            a.cow_fork(1, 0)

    def test_shared_admission_charges_only_private_pages(self):
        """The accounting fix: a prefix-hot request admits against its
        *unshared* page count, so sharing admits deeper than the free list
        alone could."""
        rng = np.random.default_rng(5)
        a = _alloc(num_pages=5, page_size=4, maxp=4)   # 4 usable
        toks = _toks(rng, 13)
        a.reserve(0, 4)                        # donor takes the whole pool
        a.ensure(0, 4)
        a.register_prefix(0, toks)             # pages 0..2 indexed
        assert a.available() == 0
        full, _ = a.match_prefix(toks)
        assert len(full) == 3
        # worst case would need 4 pages -> inadmissible; with 3 full hits the
        # charge is 1... which the pool doesn't have either. Free one donor
        # page worth by retiring a second throwaway slot? Simpler: assert the
        # charged quantity is what can_admit sees.
        assert not a.can_admit(4 - len(full) + 3)      # worst case: no
        assert not a.can_admit(1)                      # pool genuinely full
        a.release(0)
        # donor gone -> its pages freed (no other refs) and unindexed
        assert a.can_admit(4)
        assert a.match_prefix(toks) == ([], None)


class TestInvariants:
    """Refcount/free-list/index invariants under random admission churn with
    prompt reuse (the sharing path), CoW forks, and retirement."""

    @SETTINGS
    @hypothesis.given(seed=st.integers(0, 10_000),
                      num_pages=st.integers(2, 24),
                      num_slots=st.integers(1, 6),
                      steps=st.integers(1, 80))
    def test_refcounts_mirror_block_tables(self, seed, num_pages, num_slots,
                                           steps):
        import random
        rng = random.Random(seed)
        ps, maxp = 4, 4
        a = PageAllocator(num_pages, ps, num_slots, maxp)
        # a small prompt pool so distinct slots often share prefixes
        prompts = [np.asarray([rng.randrange(8) for _ in range(ps * maxp)],
                              np.int32) for _ in range(3)]
        slot_prompt = [None] * num_slots
        for _ in range(steps):
            slot = rng.randrange(num_slots)
            op = rng.random()
            busy = a.owned(slot) or a._reserved[slot]
            if op < 0.45 and not busy:
                toks = prompts[rng.randrange(len(prompts))]
                plen = rng.randrange(2, len(toks) + 1)
                toks = toks[:plen]
                need = pages_for(plen, ps)
                full, partial = a.match_prefix(toks)
                charge = need - len(full)
                if not a.can_admit(charge):
                    continue
                a.reserve(slot, charge)
                a.adopt(slot, full)
                if partial is not None:
                    a.adopt(slot, [partial[0]])
                    src, dst = a.cow_fork(slot, len(full))
                    assert dst != src and a.refcount(dst) == 1
                a.ensure(slot, rng.randint(len(a.owned(slot)), need))
                if len(a.owned(slot)) >= need:
                    a.register_prefix(slot, toks)
                slot_prompt[slot] = toks
            elif op < 0.7 and busy:
                grown = len(a.owned(slot)) + int(a._reserved[slot])
                a.ensure(slot, rng.randint(len(a.owned(slot)), grown))
            elif busy:
                a.release(slot)
                slot_prompt[slot] = None
            # -- the invariants ------------------------------------------
            owned = [p for s in range(num_slots) for p in a.owned(s)]
            assert a.live_refs() == len(owned), \
                "refcounts out of sync with block tables"
            assert NULL_PAGE not in owned, "null page handed out"
            assert all(a.refcount(p) == 0 for p in a._free), \
                "page freed while refcount > 0"
            live = {p for p in owned}
            assert len(a._free) + len(live) == num_pages - 1, "page leak"
            assert a.available() >= 0, "over-promised pages"
            assert a.high_water <= num_pages - 1
            for _, pid in a._index.values():
                assert a.refcount(pid) > 0, "index points at a freed page"
            t = a.table()
            for s in range(num_slots):
                n = len(a.owned(s))
                assert list(t[s, :n]) == a.owned(s)
                assert (t[s, n:] == NULL_PAGE).all()


class TestPinnedCache:
    """pin_pages > 0: refcount-zero indexed chains survive as cache entries,
    revived by adoption, evicted immune-weighted-LRU under pressure."""

    def _alloc(self, num_pages=9, pin=4, classes=2):
        return PageAllocator(num_pages, 4, 2, 4, pin_pages=pin,
                             num_classes=classes, require_reservation=False)

    def test_release_pins_indexed_chain_and_adopt_revives(self):
        a = self._alloc()
        toks = np.arange(12, dtype=np.int32)       # 3 full pages
        a.ensure(0, 3)
        a.register_prefix(0, toks)
        chain = a.owned(0)
        a.release(0)
        assert a.pages_pinned == 3 and a.pins == 3
        assert set(chain) == a._pinned
        assert all(a.refcount(p) == 0 for p in chain)
        assert a.pages_in_use == 3                 # resident but unowned
        assert a.available() == a.usable_pages     # yet fully reclaimable
        full, partial = a.match_prefix(toks)
        assert full == chain[:2] and partial == (chain[2], 3)
        a.adopt(1, full + [partial[0]], rclass=1)
        assert a.pinned_hits == 3 and a.pages_pinned == 0
        assert all(a.refcount(p) == 1 for p in chain)

    def test_pin_budget_zero_frees_on_zero(self):
        a = self._alloc(pin=0)
        a.ensure(0, 3)
        a.register_prefix(0, np.arange(12, dtype=np.int32))
        a.release(0)
        assert a.pages_pinned == 0 and a.pages_in_use == 0

    def test_budget_evicts_strictly_colder_chain(self):
        a = self._alloc(pin=2)
        ta = np.arange(8, dtype=np.int32)
        tb = np.arange(8, dtype=np.int32) + 100
        a.ensure(0, 2)
        a.register_prefix(0, ta)
        a.release(0)                  # pins both of A's pages (budget 2)
        assert a.pages_pinned == 2
        a.ensure(1, 2)
        a.register_prefix(1, tb)
        a.release(1)                  # B is warmer (later stamp): evicts A
        assert a.pages_pinned == 2 and a.evictions == 2
        assert a.match_prefix(ta) == ([], None)
        full, partial = a.match_prefix(tb)
        assert len(full) == 1 and partial is not None

    def test_class_value_outweighs_recency(self):
        """The immune weight in the eviction score: a chain whose class keeps
        adopting pages is not displaced by a newer chain of a class with no
        remembered prefix value."""
        a = self._alloc(pin=2, classes=2)
        ta = np.arange(8, dtype=np.int32)
        tb = np.arange(8, dtype=np.int32) + 50
        a.ensure(0, 2)
        a.register_prefix(0, ta, rclass=1)
        a.release(0)
        for _ in range(3):            # class 1 keeps coming back for A
            full, partial = a.match_prefix(ta)
            a.adopt(1, full + [partial[0]], rclass=1)
            a.release(1)
        assert a.pages_pinned == 2
        a.ensure(1, 2)
        a.register_prefix(1, tb, rclass=0)
        a.release(1)                  # class 0 never adopted anything
        assert a.match_prefix(ta)[0], "high-value chain evicted by cold class"
        assert a.match_prefix(tb) == ([], None)
        assert a.pages_pinned == 2

    def test_take_page_evicts_pinned_before_raising(self):
        a = PageAllocator(4, 4, 2, 4, pin_pages=3,
                          require_reservation=False)   # 3 usable
        a.ensure(0, 2)
        a.register_prefix(0, np.arange(8, dtype=np.int32))
        a.release(0)
        assert a.pages_pinned == 2 and a.available() == 3
        a.ensure(1, 3)                # needs all 3: evicts the pinned chain
        assert a.pages_pinned == 0 and a.evictions == 2
        with pytest.raises(OutOfPages):
            a.ensure(1, 4)            # pool truly dry: the preemption signal

    def test_reservation_mode_never_raises_out_of_pages(self):
        a = PageAllocator(4, 4, 2, 4, pin_pages=3)     # require_reservation
        a.reserve(0, 2)
        a.ensure(0, 2)
        with pytest.raises(RuntimeError, match="reservation"):
            a.ensure(0, 3)


class TestPinnedChurn:
    """Cache invariants under random churn in preemption mode: pinned pages
    are never free or refcounted, the budget holds, conservation holds, the
    index never points at a freed page, and OutOfPages is recoverable by
    releasing (preempting) the stalling slot."""

    @SETTINGS
    @hypothesis.given(seed=st.integers(0, 10_000),
                      num_pages=st.integers(4, 24),
                      pin_pages=st.integers(0, 8),
                      num_slots=st.integers(1, 4),
                      steps=st.integers(1, 80))
    def test_pinned_cache_churn_invariants(self, seed, num_pages, pin_pages,
                                           num_slots, steps):
        import random
        rng = random.Random(seed)
        ps, maxp = 4, 4
        a = PageAllocator(num_pages, ps, num_slots, maxp, pin_pages=pin_pages,
                          num_classes=3, require_reservation=False)
        prompts = [np.asarray([rng.randrange(8) for _ in range(ps * maxp)],
                              np.int32) for _ in range(3)]
        for _ in range(steps):
            slot = rng.randrange(num_slots)
            op = rng.random()
            busy = bool(a.owned(slot))
            try:
                if op < 0.45 and not busy:
                    rc = rng.randrange(3)
                    toks = prompts[rng.randrange(len(prompts))]
                    toks = toks[:rng.randrange(2, len(toks) + 1)]
                    need = pages_for(len(toks), ps)
                    full, partial = a.match_prefix(toks)
                    a.adopt(slot, full, rclass=rc)
                    if partial is not None:
                        a.adopt(slot, [partial[0]], rclass=rc)
                        src, dst = a.cow_fork(slot, len(full))
                        assert dst != src and a.refcount(dst) == 1
                    a.ensure(slot, need)
                    a.register_prefix(slot, toks, rclass=rc)
                elif op < 0.7 and busy:
                    a.ensure(slot, min(maxp, len(a.owned(slot)) + 1))
                elif busy:
                    a.release(slot)
            except OutOfPages:
                a.release(slot)       # self-preempt, as the engine would
            # -- the cache invariants -------------------------------------
            owned = [p for s in range(num_slots) for p in a.owned(s)]
            live = set(owned)
            assert a.live_refs() == len(owned)
            assert not (a._pinned & set(a._free)), "page pinned AND free"
            assert not (a._pinned & live), "page pinned AND refcounted"
            assert all(a.refcount(p) == 0 for p in a._pinned)
            assert a.pages_pinned <= a.pin_pages
            assert len(a._free) + len(live) + a.pages_pinned == \
                a.usable_pages, "conservation violated"
            assert a.available() >= 0
            for _, pid in a._index.values():
                assert a.refcount(pid) > 0 or pid in a._pinned, \
                    "index points at a freed page"
            for key, (node, _) in a._index.items():
                for kid in a._node_kids.get(node, ()):
                    assert a.refcount(kid) > 0 or kid in a._pinned, \
                        "indexed chain has a freed child"
        for s in range(num_slots):
            if a.owned(s):
                a.release(s)
        assert a.live_refs() == 0
        assert a.pages_in_use == a.pages_pinned   # drained: cache only


class TestSeizeRestoreChurn:
    """Fault-injection capacity shocks (``seize`` / ``restore``) interleaved
    with admit/grow/release churn: the conservation invariant
    ``free + live + pinned == usable`` must hold at every step even while
    ``usable_pages`` itself moves, seized pages must never be free, live, or
    pinned, and a full restore must return the pool to its nominal size.
    This is the allocator-level face of crash/rejoin cycles on surviving
    replicas — the fleet-level version lives in tests/test_faults.py."""

    def test_seize_prefers_free_then_pinned_never_live(self):
        a = PageAllocator(6, 4, 2, 4, pin_pages=4, num_classes=2,
                          require_reservation=False)
        toks = np.arange(8, dtype=np.int32)
        a.ensure(0, 2)
        a.register_prefix(0, toks)
        a.release(0)                       # both pages pin
        assert a.pages_pinned == 2
        a.ensure(1, 2)                     # two live pages
        # free=1, pinned=2, live=2, usable=5 -> seize 3 = 1 free + 2 pinned
        assert a.seize(4) == 3             # live pages are never seized
        assert a.pages_seized == 3 and a.pages_pinned == 0
        assert a.usable_pages == 2 and len(a._free) == 0
        assert a.restore() == 3
        assert a.usable_pages == 5 and a.pages_seized == 0

    def test_partial_restore_is_lifo(self):
        a = PageAllocator(6, 4, 1, 4, require_reservation=False)
        assert a.seize(3) == 3
        assert a.restore(1) == 1
        assert a.pages_seized == 2 and a.usable_pages == 3
        assert a.restore(99) == 2          # clamped to what is seized
        assert a.pages_seized == 0

    @SETTINGS
    @hypothesis.given(seed=st.integers(0, 10_000),
                      num_pages=st.integers(4, 24),
                      pin_pages=st.integers(0, 6),
                      steps=st.integers(1, 80))
    def test_conservation_under_pressure_churn(self, seed, num_pages,
                                               pin_pages, steps):
        import random
        rng = random.Random(seed)
        ps, maxp, num_slots = 4, 4, 3
        a = PageAllocator(num_pages, ps, num_slots, maxp, pin_pages=pin_pages,
                          num_classes=2, require_reservation=False)
        prompts = [np.asarray([rng.randrange(8) for _ in range(ps * maxp)],
                              np.int32) for _ in range(2)]
        for _ in range(steps):
            op = rng.random()
            slot = rng.randrange(num_slots)
            busy = bool(a.owned(slot))
            try:
                if op < 0.25:                      # pressure shock
                    a.seize(rng.randrange(1, num_pages))
                elif op < 0.45:                    # shock expires
                    a.restore(rng.randrange(1, num_pages)
                              if rng.random() < 0.5 else None)
                elif op < 0.7 and not busy:        # admit (adopt-then-index,
                    toks = prompts[rng.randrange(2)]  # the engine's contract)
                    toks = toks[:rng.randrange(ps, len(toks) + 1)]
                    full, _ = a.match_prefix(toks)
                    a.adopt(slot, full)
                    a.ensure(slot, pages_for(len(toks), ps))
                    if rng.random() < 0.5:
                        a.register_prefix(slot, toks)
                elif op < 0.85 and busy:           # decode growth
                    a.ensure(slot, min(maxp, len(a.owned(slot)) + 1))
                elif busy:                         # retire / evacuate
                    a.release(slot)
            except OutOfPages:
                if busy:
                    a.release(slot)   # self-preempt, as the engine would
            owned = [p for s in range(num_slots) for p in a.owned(s)]
            live = set(owned)
            seized = set(a._seized)
            assert a.live_refs() == len(owned)
            assert not (seized & set(a._free)), "page seized AND free"
            assert not (seized & live), "page seized AND refcounted"
            assert not (seized & a._pinned), "page seized AND pinned"
            assert a.usable_pages == a.num_pages - 1 - len(seized)
            assert len(a._free) + len(live) + a.pages_pinned == \
                a.usable_pages, "conservation violated under pressure"
            assert a.available() >= 0
        a.restore()
        for s in range(num_slots):
            if a.owned(s):
                a.release(s)
        assert a.usable_pages == a.num_pages - 1
        assert len(a._free) + a.pages_pinned == a.usable_pages
