"""Paged KV allocator: unit behavior + churn invariants.

The allocator is pure host logic, so these tests run in microseconds; the
hypothesis case drives random admit/grow/release sequences and checks the
layout invariants the device side silently relies on — above all that no two
live slots ever share a physical page (a violation would silently corrupt
another request's KV, which token-parity tests can only catch by luck).
"""
import hypothesis
import hypothesis.strategies as st
import pytest

from repro.serve.paging import NULL_PAGE, PageAllocator, pages_for

SETTINGS = hypothesis.settings(deadline=None, max_examples=60)


def _alloc(num_pages=9, page_size=16, num_slots=3, maxp=4):
    return PageAllocator(num_pages, page_size, num_slots, maxp)


class TestPagesFor:
    def test_rounds_up(self):
        assert pages_for(1, 16) == 1
        assert pages_for(16, 16) == 1
        assert pages_for(17, 16) == 2
        assert pages_for(64, 16) == 4


class TestAllocFreeReuse:
    def test_alloc_free_reuse_cycle(self):
        a = _alloc()
        a.reserve(0, 3)
        a.ensure(0, 3)
        first = a.owned(0)
        assert len(first) == 3 and NULL_PAGE not in first
        assert a.pages_in_use == 3
        a.release(0)
        assert a.pages_in_use == 0 and a.owned(0) == []
        # freed pages are reusable by another slot
        a.reserve(1, 4)
        a.ensure(1, 4)
        assert set(first) <= set(a.owned(1)) | set(a._free)
        assert a.high_water == 4

    def test_table_maps_logical_to_physical_in_order(self):
        a = _alloc()
        a.reserve(2, 2)
        a.ensure(2, 2)
        t = a.table()
        assert t.shape == (3, 4)
        assert list(t[2, :2]) == a.owned(2)
        assert (t[2, 2:] == NULL_PAGE).all()
        assert (t[:2] == NULL_PAGE).all()

    def test_release_returns_unused_reservation(self):
        """Early EOS: a slot that reserved 4 but only touched 1 page gives the
        other 3 promises back."""
        a = _alloc()
        a.reserve(0, 4)
        a.ensure(0, 1)
        assert a.available() == 8 - 1 - 3
        a.release(0)
        assert a.available() == 8 and a.pages_in_use == 0

    def test_fragmentation_churn_has_no_leak(self):
        """Interleaved alloc/free of mixed sizes: conservation holds and the
        full pool is reachable again after the churn."""
        a = _alloc(num_pages=17, num_slots=4, maxp=4)
        for round_ in range(50):
            slot = round_ % 4
            if a.owned(slot):
                a.release(slot)
            need = 1 + (round_ * 7) % 4
            if a.can_admit(need):
                a.reserve(slot, need)
                a.ensure(slot, need)
        for slot in range(4):
            a.release(slot)
        assert a.pages_in_use == 0 and a.available() == 16


class TestBackpressure:
    def test_out_of_pages_is_not_an_error(self):
        a = _alloc(num_pages=5, maxp=4)        # 4 usable
        a.reserve(0, 3)
        assert not a.can_admit(2)              # only 1 unpromised page left
        assert a.can_admit(1)
        a.release(0)
        assert a.can_admit(4)

    def test_reservation_guards_lazy_growth(self):
        a = _alloc(num_pages=5, maxp=4)
        a.reserve(0, 2)
        with pytest.raises(RuntimeError, match="reservation"):
            a.ensure(0, 3)                     # growing past the promise

    def test_max_pages_per_slot_is_enforced(self):
        a = _alloc(num_pages=17, maxp=2)
        assert not a.can_admit(3)
        a.reserve(0, 2)
        with pytest.raises(RuntimeError, match="max_pages_per_slot"):
            a.ensure(0, 3)


class TestInvariants:
    """No two live slots ever share a page — plus conservation — under random
    admit/grow/release churn."""

    @SETTINGS
    @hypothesis.given(seed=st.integers(0, 10_000),
                      num_pages=st.integers(2, 24),
                      num_slots=st.integers(1, 6),
                      steps=st.integers(1, 80))
    def test_no_two_live_slots_share_a_page(self, seed, num_pages, num_slots,
                                            steps):
        import random
        rng = random.Random(seed)
        maxp = 4
        a = PageAllocator(num_pages, 16, num_slots, maxp)
        for _ in range(steps):
            slot = rng.randrange(num_slots)
            op = rng.random()
            if op < 0.4 and not a.owned(slot) and not a._reserved[slot]:
                need = rng.randint(1, maxp)
                if a.can_admit(need):
                    a.reserve(slot, need)
                    a.ensure(slot, rng.randint(0, need))
            elif op < 0.7 and (a.owned(slot) or a._reserved[slot]):
                grown = len(a.owned(slot)) + int(a._reserved[slot])
                a.ensure(slot, rng.randint(len(a.owned(slot)), grown))
            elif a.owned(slot) or a._reserved[slot]:
                a.release(slot)
            # -- the invariants ------------------------------------------
            owned = [p for s in range(num_slots) for p in a.owned(s)]
            assert len(owned) == len(set(owned)), "two slots share a page"
            assert NULL_PAGE not in owned, "null page handed out"
            assert len(a._free) + len(owned) == num_pages - 1, "page leak"
            assert a.available() >= 0, "over-promised pages"
            assert a.high_water <= num_pages - 1
            t = a.table()
            for s in range(num_slots):
                n = len(a.owned(s))
                assert list(t[s, :n]) == a.owned(s)
                assert (t[s, n:] == NULL_PAGE).all()
