"""Optimizer, schedules, data pipeline, trainer fault tolerance, checkpointing."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.data import pipeline
from repro.dist import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train import train_step as ts
from repro.train.trainer import Trainer

jax.config.update("jax_platform_name", "cpu")


class TestOptimizer:
    def _rosenbrockish(self, factored):
        params = {"w": jnp.asarray([[2.0, -3.0], [1.5, 0.5]])}
        tcfg = TrainConfig(learning_rate=0.05, warmup_steps=0, decay_steps=10000,
                           weight_decay=0.0, grad_clip=1e9)
        state = opt.init_opt_state(params, factored=factored)
        loss = lambda p: jnp.sum(jnp.square(p["w"] - 1.0))
        for _ in range(300):
            grads = jax.grad(loss)(params)
            params, state, _ = opt.adamw_update(grads, state, params, tcfg)
        return float(loss(params))

    def test_adamw_converges(self):
        assert self._rosenbrockish(factored=False) < 1e-3

    def test_factored_adamw_converges(self):
        assert self._rosenbrockish(factored=True) < 1e-2

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = opt.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(opt.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_wsd_schedule_shape(self):
        tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10, decay_steps=100,
                           schedule="wsd", stable_frac=0.8)
        lr = [float(opt.schedule(tcfg, jnp.asarray(s))) for s in range(110)]
        assert lr[0] == 0.0 and lr[10] == pytest.approx(1.0)
        assert lr[50] == pytest.approx(1.0)            # stable plateau
        assert lr[79] == pytest.approx(1.0)
        assert lr[90] < 0.7 and lr[100] < 0.05          # 1-sqrt tail

    def test_cosine_schedule_endpoints(self):
        tcfg = TrainConfig(learning_rate=1.0, warmup_steps=0, decay_steps=100,
                           schedule="cosine")
        assert float(opt.schedule(tcfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)

    def test_factored_state_is_small(self):
        params = {"w": jnp.zeros((256, 512))}
        full = opt.init_opt_state(params, factored=False)
        fact = opt.init_opt_state(params, factored=True)
        full_nu = sum(x.size for x in jax.tree.leaves(full.nu))
        fact_nu = sum(x.size for x in jax.tree.leaves(fact.nu))
        assert fact_nu < full_nu / 100


class TestData:
    def test_deterministic_and_stateless(self):
        cfg = configs.get_config("smollm-360m").smoke()
        st = pipeline.init_data_state()
        b1, st1 = pipeline.sample_batch(cfg, 4, 32, st)
        b2, _ = pipeline.sample_batch(cfg, 4, 32, st)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3, _ = pipeline.sample_batch(cfg, 4, 32, st1)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_resume_from_step_counter(self):
        cfg = configs.get_config("smollm-360m").smoke()
        st = pipeline.init_data_state()
        seen = []
        for _ in range(3):
            b, st = pipeline.sample_batch(cfg, 2, 16, st)
            seen.append(np.asarray(b["tokens"]))
        st_resumed = pipeline.DataState(step=jnp.asarray(1, jnp.int32))
        b, _ = pipeline.sample_batch(cfg, 2, 16, st_resumed)
        np.testing.assert_array_equal(b["tokens"], seen[1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = {"a": jnp.arange(10, dtype=jnp.float32),
                 "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        ckpt.save(str(tmp_path), state, step=5)
        restored, step = ckpt.restore(str(tmp_path), state)
        assert step == 5
        np.testing.assert_array_equal(restored["a"], state["a"])
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_corrupt_checkpoint_falls_back(self, tmp_path):
        state = {"a": jnp.arange(4.0)}
        ckpt.save(str(tmp_path), state, step=1)
        ckpt.save(str(tmp_path), {"a": jnp.arange(4.0) * 2}, step=2)
        # corrupt the newest
        with open(os.path.join(str(tmp_path), "step_00000002", "leaf_00000.npy"),
                  "wb") as f:
            f.write(b"garbage")
        restored, step = ckpt.restore(str(tmp_path), state)
        assert step == 1
        np.testing.assert_array_equal(restored["a"], jnp.arange(4.0))

    def test_retention(self, tmp_path):
        state = {"a": jnp.zeros(2)}
        for s in range(6):
            ckpt.save(str(tmp_path), state, step=s, keep=3)
        assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5]

    def test_retention_with_fewer_than_keep(self, tmp_path):
        """keep larger than what exists must delete nothing (regression: the
        prune slice went negative and ate the oldest checkpoints)."""
        state = {"a": jnp.zeros(2)}
        for s in range(1, 7):
            ckpt.save(str(tmp_path), state, step=s, keep=4)
        assert ckpt.all_steps(str(tmp_path)) == [3, 4, 5, 6]

    def test_retention_spares_fallback_survivors(self, tmp_path):
        """Pruning is relative to the step just saved: a corrupt newer
        checkpoint we resumed past must not cause keep= to delete the good
        checkpoints written after the fallback."""
        state = {"a": jnp.arange(4.0)}
        ckpt.save(str(tmp_path), state, step=10)
        ckpt.save(str(tmp_path), {"a": jnp.arange(4.0) * 5}, step=50)
        with open(os.path.join(str(tmp_path), "step_00000050",
                               "leaf_00000.npy"), "wb") as f:
            f.write(b"garbage")
        restored, step = ckpt.restore(str(tmp_path), state)
        assert step == 10
        ckpt.save(str(tmp_path), restored, step=20, keep=1)
        restored2, step2 = ckpt.restore(str(tmp_path), state)
        assert step2 == 20 and restored2 is not None

    def test_torn_save_is_invisible(self, tmp_path):
        """A crash mid-save (scratch dir never renamed) must not shadow the last
        good checkpoint, and the next save must sweep the debris."""
        state = {"a": jnp.arange(4.0)}
        ckpt.save(str(tmp_path), state, step=1)
        torn = tmp_path / "step_00000002.tmp.deadbeef"
        torn.mkdir()
        (torn / "leaf_00000.npy").write_bytes(b"partial")
        assert ckpt.all_steps(str(tmp_path)) == [1]
        restored, step = ckpt.restore(str(tmp_path), state)
        assert step == 1 and restored is not None
        ckpt.save(str(tmp_path), state, step=3)
        assert not torn.exists(), "scratch dir from a crashed save not swept"

    def test_restore_empty_dir(self, tmp_path):
        restored, step = ckpt.restore(str(tmp_path), {"a": jnp.zeros(2)})
        assert restored is None and step == 0


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path, **kw):
        cfg = configs.get_config("smollm-360m").smoke()
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=32, num_heads=2,
                                  num_kv_heads=1, head_dim=16, d_ff=64,
                                  vocab_size=128)
        tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=1000)
        return Trainer(cfg=cfg, tcfg=tcfg, workdir=str(tmp_path), batch=4,
                       seq=32, ckpt_every=10, log_every=5, **kw)

    def test_loss_decreases(self, tmp_path):
        tr = self._mk(tmp_path)
        tr.train(40)
        first = tr.history[0]["loss"]
        last = tr.history[-1]["loss"]
        assert last < first - 0.2, (first, last)

    def test_failure_recovery_is_bitwise_identical(self, tmp_path):
        # uninterrupted run
        tr_a = self._mk(tmp_path / "a")
        state_a = tr_a.train(30)
        # interrupted at step 17 (past the step-10 checkpoint), then resumed
        tr_b = self._mk(tmp_path / "b", failure_at=17)
        with pytest.raises(RuntimeError, match="injected node failure"):
            tr_b.train(30)
        tr_b2 = self._mk(tmp_path / "b")
        state_b = tr_b2.train(30)
        for la, lb in zip(jax.tree.leaves(state_a.params),
                          jax.tree.leaves(state_b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


class TestTrainStepMoE:
    def test_router_state_regulates_during_training(self):
        cfg = configs.get_config("granite-moe-3b-a800m").smoke()
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=0, decay_steps=1000)
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
        data = pipeline.init_data_state()
        step = jax.jit(lambda st, b: ts.train_step(st, b, cfg, tcfg))
        cvs = []
        for _ in range(8):
            batch, data = pipeline.sample_batch(cfg, 4, 32, data)
            state, metrics = step(state, batch)
            cvs.append(float(metrics.load_cv))
        assert np.isfinite(cvs).all()
        assert not np.array_equal(np.asarray(state.router.bias), 0.0), \
            "router bias never updated"
