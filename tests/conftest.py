"""Shared test configuration.

Installs the deterministic ``hypothesis`` fallback (_hypothesis_fallback.py)
when the real package is missing, so airgapped environments still collect and
run the property-test modules.

(JAX's persistent compilation cache was evaluated here to hide the VLSI agent
model's 20-30 s XLA CPU compiles on warm runs, and rejected: with
``donate_argnums`` in play, deserialized CPU executables produced NaNs and
heap corruption under jax 0.4.37. Do not re-enable without a correctness soak;
see ROADMAP "Open items".)
"""
import importlib.util
import os
import sys


def _ensure_hypothesis() -> None:
    if importlib.util.find_spec("hypothesis") is not None:
        return
    path = os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py")
    spec = importlib.util.spec_from_file_location("hypothesis", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    mod.install(mod)               # single registration point for sys.modules


_ensure_hypothesis()
