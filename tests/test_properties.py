"""Hypothesis property tests on system invariants."""
import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import router as irouter
from repro.core.vlsi import layout, reference
from repro.kernels.grid_step import grid_step, grid_step_ref
from repro.models import moe

jax.config.update("jax_platform_name", "cpu")
SETTINGS = hypothesis.settings(deadline=None, max_examples=12)


class TestMoEDispatchInvariants:
    @hypothesis.given(seed=st.integers(0, 10_000), cf=st.floats(0.3, 4.0),
                      groups=st.sampled_from([1, 2, 4]))
    @SETTINGS
    def test_combine_is_partial_sum_of_selected_experts(self, seed, cf, groups):
        """Invariant: whatever is dropped, every surviving slot contributes
        gate-weighted expert output, and the result is finite with bounded norm."""
        cfg = dataclasses.replace(configs.get_config("granite-moe-3b-a800m").smoke(),
                                  capacity_factor=cf, dispatch_groups=groups)
        key = jax.random.PRNGKey(seed)
        params = moe.init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
        y, stats = moe.moe_ffn(params, x, cfg, jnp.zeros((cfg.num_experts,)))
        y_ref = moe.moe_ffn_reference(params, x, cfg,
                                      jnp.zeros((cfg.num_experts,)))
        assert bool(jnp.all(jnp.isfinite(y)))
        assert 0.0 <= float(stats.drop_frac) <= 1.0
        # dropping only ever *removes* contributions (per-token output norm bounded
        # by the no-drop reference norm up to numerics)
        ratio = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1) \
            / (jnp.linalg.norm(y_ref.reshape(-1, cfg.d_model), axis=-1) + 1e-6)
        assert float(jnp.max(ratio)) < 1.05

    @hypothesis.given(seed=st.integers(0, 10_000))
    @SETTINGS
    def test_load_fractions_sum_to_one(self, seed):
        idx = jax.random.randint(jax.random.PRNGKey(seed), (64, 2), 0, 8)
        load = irouter.load_fractions(idx, 8)
        np.testing.assert_allclose(float(jnp.sum(load)), 1.0, rtol=1e-5)


class TestGridStepInvariants:
    @hypothesis.given(seed=st.integers(0, 10_000),
                      h=st.sampled_from([8, 24, 40]),
                      w=st.sampled_from([16, 32]))
    @SETTINGS
    def test_matches_oracle_and_monotone(self, seed, h, w):
        key = jax.random.PRNGKey(seed)
        cond = (jax.random.uniform(key, (h, w)) < 0.55).astype(jnp.int32)
        lab = jax.random.randint(jax.random.fold_in(key, 1), (h, w), 0, 99) * cond
        out = grid_step(lab, cond, interpret=True)
        assert bool(jnp.all(out == grid_step_ref(lab, cond)))
        assert bool(jnp.all(out >= lab)), "max-diffusion must be monotone"
        assert bool(jnp.all(jnp.where(cond == 0, out == lab, True))), \
            "non-conductor cells must not change"


class TestOracleInvariants:
    @hypothesis.given(seed=st.integers(0, 10_000))
    @SETTINGS
    def test_random_layouts_well_formed(self, seed):
        rng = np.random.default_rng(seed)
        lay = layout.random_layout(rng, rows=1, cols=2)
        net = reference.extract(lay)   # raises on design-rule violations
        for f in net.fets:
            assert len(f.sd) == 2, "every FET must have two distinct diff sides"
            assert f.l >= 1 and f.w >= f.l
        for e in net.equivs:
            assert len(e.nodes) == 2


class TestCollectiveParser:
    def test_while_body_multiplier(self):
        from repro.launch import dryrun
        hlo = (
            '%ag = f32[8,16]{1,0} all-gather(f32[1,16] %x), dims={0}\n'
            '%ar = f32[4,4]{1,0} all-reduce(f32[4,4] %y), to_apply=%sum, '
            'metadata={op_name="jit(f)/while/body/mul"}\n'
        )
        total, by_kind = dryrun.collective_bytes(hlo, scan_trips=10)
        # ag: 8*16*4 = 512 (x1); ar: 4*4*4*2 (ring) * 10 trips = 1280
        assert by_kind["all-gather"] == 512
        assert by_kind["all-reduce"] == 1280
        assert total == 1792
