"""Self-speculative decoding (serve.spec) + the sampling-surface satellites.

The accept oracle is *bitwise*, not statistical: a spec engine's verify step
reads the paged KV through the same gather + SDPA contraction as the plain
decode tick and accepts by the same argmax reduction, so every emitted token
must equal the non-speculative engine's — dense and MoE, through preemption
replay and journal recovery. Acceptance *rate* only moves throughput, never
tokens (``make_draft_friendly`` raises it so the speedup machinery is
actually exercised; parity would hold at any rate).

Satellites pinned here alongside the tentpole:

  * penalties (repetition/presence/frequency) — neutral values are bitwise
    the unpenalized path even beside penalized neighbours in the same
    compiled step; nonzero values change tokens and still replay exactly
    engine-vs-oneshot;
  * top-k alternative logprobs (``SamplingParams.logprobs == k``) — ids
    exact, values to 1e-5, engine-vs-oneshot, per-request k in one batch;
  * spec gating — sampled / penalized / logprob-recording residents force
    plain ticks (their per-emitted-token key/count discipline cannot ride a
    multi-token tick), with parity intact either way.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import api, decode
from repro.serve import durability
from repro.serve import engine as eng_mod
from repro.serve import router as rt_mod
from repro.serve import spec as spec_mod
from repro.serve import traces
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.faults import FaultInjector, FaultPlan

jax.config.update("jax_platform_name", "cpu")

DEPTH = 1                 # draft depth for the 2-layer smoke stacks


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    # draft-friendly so spec ticks actually accept (parity is rate-agnostic,
    # but a ~zero accept rate would leave the speedup machinery untested)
    return cfg, spec_mod.make_draft_friendly(params, cfg, DEPTH)


@pytest.fixture(scope="module")
def moe():
    cfg = configs.get_config("granite-moe-3b-a800m").smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, spec_mod.make_draft_friendly(params, cfg, DEPTH)


def _ecfg(**kw):
    base = dict(num_slots=2, max_cache=64, page_size=16, prefill_chunk=8,
                policy="fifo", spec_decode=4, spec_draft_layers=DEPTH)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _reqs(cfg, n, seed=0, plens=(6, 10), steps=(8, 12), stagger=1, **pkw):
    rng = np.random.default_rng(seed)
    out = []
    for rid in range(n):
        out.append(ServeRequest(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size,
                                size=plens[rid % len(plens)]).astype(np.int32),
            params=SamplingParams(max_new_tokens=steps[rid % len(steps)],
                                  seed=100 + rid, **pkw),
            rclass=rid % 2, arrival=rid * stagger))
    return out


def _tokens_by_rid(source) -> dict:
    reqs = source.completed if hasattr(source, "completed") else source
    return {r.rid: list(r.out_tokens) for r in reqs}


def _replay(params, cfg, req, max_cache):
    probe = ServeRequest(rid=req.rid, tokens=req.tokens, params=req.params)
    out = api.generate(params, cfg, probe, max_cache=max_cache)
    return probe, out


# ---------------------------------------------------------------------------
# accept rule + config validation (model-free)
# ---------------------------------------------------------------------------
class TestAcceptRule:
    def test_accept_length_is_longest_matching_prefix(self):
        assert spec_mod.accept_length([3, 5, 7], [3, 5, 9, 1], 3) == 2
        assert spec_mod.accept_length([3, 5, 7], [3, 5, 7, 1], 3) == 3
        assert spec_mod.accept_length([4, 5, 7], [3, 5, 7, 1], 3) == 0
        assert spec_mod.accept_length([], [9], 0) == 0

    def test_spec_config_validation(self):
        cfg = configs.get_config("smollm-360m").smoke()
        params = model.init_params(jax.random.PRNGKey(0), cfg)
        for depth in (0, cfg.num_layers):
            with pytest.raises(ValueError, match="spec_draft_layers"):
                eng_mod.Engine(params, cfg,
                               _ecfg(spec_draft_layers=depth))
        with pytest.raises(ValueError, match="spec_decode"):
            eng_mod.Engine(params, cfg, _ecfg(spec_decode=-1))

    def test_draft_friendly_returns_ordinary_params(self, dense):
        cfg, params = dense
        # same tree structure, only deep wo/w_down leaves rescaled
        assert jax.tree_util.tree_structure(params) \
            == jax.tree_util.tree_structure(
                model.init_params(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# the bitwise accept oracle: dense + MoE
# ---------------------------------------------------------------------------
class TestSpecParity:
    def test_dense_spec_bitwise_matches_nonspec_and_oneshot(self, dense):
        """The tentpole invariant: a greedy spec engine emits token-bitwise
        the non-speculative engine's streams, and both match the one-shot
        oracle — speculation changes when logits are computed, never what
        they are. Spec ticks must actually fire and accept for the run to be
        non-vacuous."""
        cfg, params = dense
        runs = {}
        for spec_k in (0, 4):
            eng = eng_mod.Engine(params, cfg, _ecfg(
                spec_decode=spec_k,
                spec_draft_layers=DEPTH if spec_k else 0))
            stats = eng.run(_reqs(cfg, 5), max_ticks=300)
            assert stats["completed"] == 5
            runs[spec_k] = (eng, stats)
        assert _tokens_by_rid(runs[4][0]) == _tokens_by_rid(runs[0][0])
        spec_eng, spec_stats = runs[4]
        assert spec_stats["spec_ticks"] > 0
        assert spec_stats["spec_accepted"] > 0
        assert spec_stats["spec_emitted"] > spec_stats["spec_ticks"], \
            "spec ticks never emitted more than one token per lane"
        assert spec_stats["ticks"] < runs[0][1]["ticks"], \
            "speculation did not shorten the run in ticks"
        for req in spec_eng.completed:
            toks, _ = decode.generate(params, cfg, req.prompts(),
                                      max_cache=64,
                                      steps=req.max_new_tokens)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"spec request {req.rid} diverged from the one-shot oracle"

    def test_dense_spec_on_agentic_trace(self, dense):
        """Spec over the workload it is built for: grown-prompt agentic turns
        whose prefixes share pages — spec ticks decode over adopted/CoW
        pages and every stream replays exactly through the facade."""
        cfg, params = dense
        reqs = traces.agentic_trace(cfg, sessions=2, turns=3, base_prompt=16,
                                    grow_lens=(4, 6), decode_lens=(6, 8),
                                    turn_gap=2)
        eng = eng_mod.Engine(params, cfg, _ecfg(max_cache=96, pin_pages=4))
        stats = eng.run(reqs, max_ticks=400)
        assert stats["completed"] == 6
        assert stats["spec_ticks"] > 0
        assert stats["shared_pages_adopted"] \
            + stats["pinned_pages_adopted"] > 0, \
            "agentic trace never exercised the prefix index"
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, 96)
            assert req.out_tokens == out.tokens, \
                f"agentic request {req.rid} diverged engine-vs-oneshot"

    def test_moe_spec_bitwise_matches_nonspec(self, moe):
        """MoE spec parity, router bias riding into draft + verify: the
        verify pass routes with exactly the plain tick's bias, so dropless
        row-count invariance keeps the accept oracle bitwise."""
        cfg, params = moe
        import jax.numpy as jnp
        bias = jnp.zeros((cfg.num_layers, cfg.num_experts))
        runs = {}
        for spec_k in (0, 3):
            eng = eng_mod.Engine(params, cfg, _ecfg(
                spec_decode=spec_k,
                spec_draft_layers=DEPTH if spec_k else 0),
                router_bias=bias)
            stats = eng.run(_reqs(cfg, 3, steps=(6, 8)), max_ticks=300)
            assert stats["completed"] == 3
            runs[spec_k] = (eng, stats)
        assert runs[3][1]["spec_ticks"] > 0
        assert _tokens_by_rid(runs[3][0]) == _tokens_by_rid(runs[0][0]), \
            "MoE spec decode changed tokens (dropless row-count invariance broke)"

    def test_spec_deterministic_across_runs(self, dense):
        cfg, params = dense

        def serve():
            eng = eng_mod.Engine(params, cfg, _ecfg())
            eng.run(_reqs(cfg, 4), max_ticks=300)
            return _tokens_by_rid(eng)

        assert serve() == serve()


# ---------------------------------------------------------------------------
# gating: residents that cannot ride a multi-token tick force plain ticks
# ---------------------------------------------------------------------------
class TestSpecGating:
    def test_sampled_residents_disable_spec_ticks(self, dense):
        cfg, params = dense
        eng = eng_mod.Engine(params, cfg, _ecfg())
        stats = eng.run(_reqs(cfg, 3, temperature=0.9, top_p=0.9),
                        max_ticks=300)
        assert stats["completed"] == 3
        assert stats["spec_ticks"] == 0, \
            "spec tick ran with sampled residents (per-token key fold broken)"
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, 64)
            assert req.out_tokens == out.tokens

    def test_logprob_residents_disable_spec_ticks(self, dense):
        cfg, params = dense
        eng = eng_mod.Engine(params, cfg, _ecfg())
        stats = eng.run(_reqs(cfg, 2, logprobs=1), max_ticks=300)
        assert stats["completed"] == 2
        assert stats["spec_ticks"] == 0
        assert all(len(r.out_logprobs) == len(r.out_tokens)
                   for r in eng.completed)

    def test_penalized_residents_disable_spec_ticks(self, dense):
        cfg, params = dense
        eng = eng_mod.Engine(params, cfg, _ecfg())
        stats = eng.run(_reqs(cfg, 2, repetition_penalty=1.3), max_ticks=300)
        assert stats["completed"] == 2
        assert stats["spec_ticks"] == 0
        assert stats["penalized_requests"] == 2


# ---------------------------------------------------------------------------
# spec through preemption replay and journal recovery
# ---------------------------------------------------------------------------
class TestSpecRecovery:
    def test_preempted_then_replayed_spec_is_bitwise(self, dense):
        """Page pressure preempts a spec-decoding resident mid-flight; its
        re-admission replays recorded tokens through spec ticks and the final
        stream is still bitwise the one-shot oracle's."""
        cfg, params = dense
        ecfg = _ecfg(num_slots=2, max_cache=96, page_size=8, num_pages=10,
                     admission_mode="preempt", prefill_chunk=8)
        hog = ServeRequest(rid=0, tokens=np.arange(16, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=40),
                           arrival=0)
        late = _reqs(cfg, 2, seed=3, plens=(24,), steps=(10,))
        for i, r in enumerate(late):
            r.rid = i + 1
            r.arrival = 2 + i
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run([hog] + late, max_ticks=500)
        assert stats["completed"] == 3
        assert stats["spec_ticks"] > 0
        assert stats["preemptions"] > 0, "page pressure never preempted"
        assert stats["replayed_tokens"] > 0
        for req in eng.completed:
            toks, _ = decode.generate(params, cfg, req.prompts(),
                                      max_cache=ecfg.max_cache,
                                      steps=req.max_new_tokens)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"request {req.rid} diverged after preemption replay"

    def test_journal_recovered_spec_is_bitwise(self, dense, tmp_path):
        """A full-fleet power loss mid-trace, recovered from the journal onto
        fresh spec-decoding replicas: every completion is bitwise the
        uninterrupted non-speculative fleet's."""
        cfg, params = dense

        def ecfg():
            return _ecfg(max_cache=96, policy="immune", num_classes=3,
                         latency_budget=96.0)

        def trace():
            return traces.agentic_trace(cfg, sessions=2, turns=2,
                                        base_prompt=16, grow_lens=(4, 6),
                                        decode_lens=(6, 8), turn_gap=6)

        ref_rt = rt_mod.Router(
            [eng_mod.Engine(params, cfg,
                            _ecfg(max_cache=96, policy="immune",
                                  num_classes=3, latency_budget=96.0,
                                  spec_decode=0, spec_draft_layers=0))
             for _ in range(2)],
            rt_mod.RouterConfig(policy="immune"))
        ref = ref_rt.run(trace())
        off = max(2, ref["ticks"] // 2)

        def factory():
            inj = FaultInjector(
                FaultPlan.parse(f"poweroff@{off} restart@{off + 3}"))
            fleet = [eng_mod.Engine(params, cfg, ecfg()) for _ in range(2)]
            return rt_mod.Router(fleet, rt_mod.RouterConfig(policy="immune"),
                                 injector=inj)

        rt, stats = durability.run_durable(factory, trace(),
                                           str(tmp_path / "wal"))
        assert stats["restarts"] == 1
        assert stats["completed"] == ref["completed"] == 4
        assert _tokens_by_rid(rt) == _tokens_by_rid(ref_rt), \
            "journal-recovered spec streams diverged from the clean fleet"
        assert sum(e.spec_ticks for e in rt.engines) > 0


# ---------------------------------------------------------------------------
# satellite: repetition / presence / frequency penalties
# ---------------------------------------------------------------------------
class TestPenalties:
    def test_neutral_penalties_bitwise_off(self, dense):
        """A neutral lane beside a penalized neighbour in the same compiled
        step emits bitwise the tokens of a run with no penalties anywhere —
        the where-mask in ``penalize_logits`` returns neutral rows
        untouched."""
        cfg, params = dense

        def serve(penalize_first):
            reqs = _reqs(cfg, 3, plens=(8,), steps=(10,), stagger=0)
            if penalize_first:
                reqs[0].params = dataclasses.replace(
                    reqs[0].params, repetition_penalty=1.4,
                    presence_penalty=0.6)
            eng = eng_mod.Engine(params, cfg, _ecfg(spec_decode=0,
                                                    spec_draft_layers=0,
                                                    num_slots=3))
            assert eng.run(reqs, max_ticks=300)["completed"] == 3
            return _tokens_by_rid(eng)

        mixed, clean = serve(True), serve(False)
        assert mixed[1] == clean[1] and mixed[2] == clean[2], \
            "a penalized neighbour perturbed neutral lanes"

    def test_nonzero_penalties_change_tokens_and_replay_exactly(self, dense):
        cfg, params = dense
        rng = np.random.default_rng(7)
        toks = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)

        def one(**pkw):
            req = ServeRequest(rid=0, tokens=toks.copy(),
                               params=SamplingParams(max_new_tokens=24, **pkw))
            return api.generate(params, cfg, req, max_cache=64).tokens

        plain = one()
        bent = one(repetition_penalty=1.8, presence_penalty=1.5,
                   frequency_penalty=1.5)
        assert plain != bent, "strong penalties left a greedy stream unchanged"

        # engine-vs-oneshot parity with penalties active (greedy + sampled)
        reqs = _reqs(cfg, 4, plens=(8,), steps=(10,),
                     repetition_penalty=1.5, frequency_penalty=0.8)
        for r in reqs[::2]:
            r.params = dataclasses.replace(r.params, temperature=0.8,
                                           top_p=0.9)
        eng = eng_mod.Engine(params, cfg, _ecfg(spec_decode=0,
                                                spec_draft_layers=0))
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4 and stats["penalized_requests"] == 4
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, 64)
            assert req.out_tokens == out.tokens, \
                f"penalized request {req.rid} diverged engine-vs-oneshot"

    def test_penalty_counts_survive_preemption_replay(self, dense):
        """The on-device count table is rebuilt at re-admission from recorded
        tokens, so a preempted penalized request still replays bitwise."""
        cfg, params = dense
        ecfg = _ecfg(spec_decode=0, spec_draft_layers=0, num_slots=2,
                     max_cache=96, page_size=8, num_pages=8,
                     admission_mode="preempt")
        reqs = _reqs(cfg, 3, plens=(16, 24), steps=(20, 10),
                     repetition_penalty=1.4)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=500)
        assert stats["completed"] == 3
        assert stats["preemptions"] > 0
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, ecfg.max_cache)
            assert req.out_tokens == out.tokens

    def test_param_validation(self):
        with pytest.raises(ValueError):
            SamplingParams(repetition_penalty=0.0)
        assert not SamplingParams().has_penalties
        assert SamplingParams(presence_penalty=0.1).has_penalties


# ---------------------------------------------------------------------------
# satellite: top-k alternative logprobs
# ---------------------------------------------------------------------------
class TestTopKLogprobs:
    def test_engine_topk_matches_oneshot(self, dense):
        """ids exact, values to 1e-5, engine-vs-oneshot — the top-k rows come
        off the raw (pre-penalty, pre-temperature) distribution on both
        backends."""
        cfg, params = dense
        reqs = _reqs(cfg, 4, plens=(8,), steps=(6,), logprobs=3)
        for r in reqs[1::2]:
            r.params = dataclasses.replace(r.params, temperature=0.8)
        eng = eng_mod.Engine(params, cfg, _ecfg(spec_decode=0,
                                                spec_draft_layers=0))
        assert eng.run(reqs, max_ticks=300)["completed"] == 4
        for req in eng.completed:
            assert len(req.out_topk) == len(req.out_tokens) > 0
            probe, out = _replay(params, cfg, req, 64)
            assert req.out_tokens == out.tokens
            assert out.top_logprobs is not None
            for i, ((ids_e, vals_e), (ids_o, vals_o)) in enumerate(
                    zip(req.out_topk, probe.out_topk)):
                assert len(ids_e) == 3
                assert ids_e == ids_o, \
                    f"request {req.rid} pos {i}: top-k ids differ"
                np.testing.assert_allclose(vals_e, vals_o, atol=1e-5)
            # rows are sorted descending and bound the chosen logprob
            for (ids_e, vals_e), lp in zip(req.out_topk, req.out_logprobs):
                assert vals_e == sorted(vals_e, reverse=True)
                assert vals_e[0] >= lp - 1e-5

    def test_per_request_k_in_one_batch(self, dense):
        """The compiled step computes the batch-max k; the host slices each
        request back to its own k."""
        cfg, params = dense
        reqs = _reqs(cfg, 2, plens=(8,), steps=(5,), stagger=0)
        reqs[0].params = dataclasses.replace(reqs[0].params, logprobs=2)
        reqs[1].params = dataclasses.replace(reqs[1].params, logprobs=5)
        eng = eng_mod.Engine(params, cfg, _ecfg(spec_decode=0,
                                                spec_draft_layers=0))
        assert eng.run(reqs, max_ticks=100)["completed"] == 2
        by_rid = {r.rid: r for r in eng.completed}
        assert all(len(ids) == 2 for ids, _ in by_rid[0].out_topk)
        assert all(len(ids) == 5 for ids, _ in by_rid[1].out_topk)
        for req in eng.completed:
            probe, _ = _replay(params, cfg, req, 64)
            assert req.out_topk[0][0] == probe.out_topk[0][0]
