"""Fleet fault injection + immune failover (serve.faults + serve.router).

Plan/injector semantics are model-free and run in microseconds; the fleet
tests drive real engine replicas through scripted crash / straggler / stall /
pressure / rejoin faults and pin the tentpole invariant: every *surviving*
request's tokens are bitwise identical to the fault-free run, across router
policies and fault plans — a crash moves work, it never changes what the
work computes. Accounting is the second anchor: no rid is ever silently
lost; every submitted request terminates completed, shed, rejected, or
``failed`` (retry budget exhausted).
"""
import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import engine as eng_mod
from repro.serve import router as rt_mod
from repro.serve import traces
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.faults import (FAULT_KINDS, FaultEvent, FaultInjector,
                                FaultPlan)
from repro.serve.paging import PageAllocator

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    return cfg, model.init_params(jax.random.PRNGKey(0), cfg)


def _ecfg(**kw):
    base = dict(num_slots=2, max_cache=64, page_size=16, prefill_chunk=8,
                policy="immune", num_classes=3, latency_budget=64.0,
                pin_pages=4)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _engines(params, cfg, n, **kw):
    return [eng_mod.Engine(params, cfg, _ecfg(**kw)) for _ in range(n)]


def _fleet(cfg, **kw):
    base = dict(tenants=3, num_requests=12, prefix_len=32, suffix_lens=(4,),
                decode_lens=(6,), hot_frac=0.5, burst_every=4, burst_size=3,
                seed=0)
    base.update(kw)
    return traces.fleet_trace(cfg, **base)


def _tokens_by_rid(router):
    return {r.rid: list(r.out_tokens) for r in router.completed}


# ---------------------------------------------------------------------------
# plan + injector semantics (model-free)
# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_parse_full_grammar(self):
        plan = FaultPlan.parse(
            "crash@40:r1, rejoin@90:r1 slow@10+30:r0:x3 stall@15+4:r2 "
            "pressure@20+10:r0:p4")
        assert len(plan) == 5
        kinds = {e.kind: e for e in plan}
        assert set(kinds) == set(FAULT_KINDS)
        assert kinds["crash"].tick == 40 and kinds["crash"].replica == 1
        assert kinds["slow"].duration == 30 and kinds["slow"].factor == 3
        assert kinds["stall"].duration == 4
        assert kinds["pressure"].pages == 4 and kinds["pressure"].duration == 10
        assert kinds["rejoin"].tick == 90

    def test_events_sorted_and_queryable(self):
        plan = FaultPlan.parse("crash@9:r2 crash@3:r0 stall@3+2:r1")
        assert [e.tick for e in plan] == [3, 3, 9]
        assert {e.kind for e in plan.events_at(3)} == {"crash", "stall"}
        assert plan.events_at(4) == []
        assert plan.max_replica() == 2

    def test_crash_of_one_helper(self):
        plan = FaultPlan.crash_of_one(replica=1, at=7, rejoin_at=20)
        assert [(e.kind, e.tick) for e in plan] == [("crash", 7),
                                                   ("rejoin", 20)]
        assert len(FaultPlan.crash_of_one(replica=0, at=7)) == 1

    @pytest.mark.parametrize("spec", [
        "melt@3:r0",                  # unknown kind
        "crash@3",                    # missing replica
        "crash@3:r0:q9",              # unknown modifier
        "slow@3:r0",                  # slow needs a duration
        "slow@3+5:r0:x1",             # factor < 2 is not slow
        "pressure@3+5:r0",            # pressure needs pages
        "rejoin@9:r1",                # rejoin without a prior crash
        "crash@3:r0 crash@5:r0",      # double crash without rejoin
    ])
    def test_invalid_specs_raise(self, spec):
        with pytest.raises(ValueError):
            FaultPlan.parse(spec)

    def test_crash_rejoin_crash_is_valid(self):
        plan = FaultPlan.parse("crash@3:r0 rejoin@9:r0 crash@15:r0")
        assert len(plan) == 3


class _StubEngine:
    def __init__(self):
        self.alloc = PageAllocator(9, 4, 2, 4, pin_pages=2,
                                   require_reservation=False)
        self.tick = 0


class _StubRouter:
    def __init__(self, n=3):
        self.tick = 0
        self.engines = [_StubEngine() for _ in range(n)]
        self.rejoined = []

    def rejoin(self, i, engine):
        self.rejoined.append(i)
        self.engines[i] = engine


class TestFaultInjector:
    def _drive(self, inj, router, ticks):
        held = {i: [] for i in range(len(router.engines))}
        for t in range(ticks):
            router.tick = t
            inj.begin_tick(router)
            for i in range(len(router.engines)):
                if not inj.can_step(i, t):
                    held[i].append(t)
        return held

    def test_crash_holds_forever_rejoin_releases(self):
        inj = FaultInjector(FaultPlan.parse("crash@2:r1 rejoin@5:r1"),
                            engine_factory=_StubEngine)
        router = _StubRouter()
        held = self._drive(inj, router, 8)
        assert held[1] == [2, 3, 4]          # released by the rejoin at 5
        assert held[0] == [] and held[2] == []
        assert router.rejoined == [1]
        assert inj.stats()["crashes"] == 1 and inj.stats()["rejoins"] == 1

    def test_stall_window_heals_itself(self):
        inj = FaultInjector(FaultPlan.parse("stall@2+3:r0"))
        held = self._drive(inj, _StubRouter(), 8)
        assert held[0] == [2, 3, 4]

    def test_slow_steps_every_factor_ticks(self):
        inj = FaultInjector(FaultPlan.parse("slow@2+6:r0:x3"))
        held = self._drive(inj, _StubRouter(), 10)
        # window [2, 8): steps at 2 and 5 only
        assert held[0] == [3, 4, 6, 7]

    def test_pressure_seizes_then_restores(self):
        inj = FaultInjector(FaultPlan.parse("pressure@1+3:r0:p4"))
        router = _StubRouter()
        alloc = router.engines[0].alloc
        nominal = alloc.usable_pages
        for t in range(6):
            router.tick = t
            inj.begin_tick(router)
            if 1 <= t < 4:
                assert alloc.pages_seized == 4
                assert alloc.usable_pages == nominal - 4
        assert alloc.pages_seized == 0 and alloc.usable_pages == nominal
        assert inj.stats()["pages_seized"] == 4

    def test_rejoin_requires_factory(self):
        with pytest.raises(ValueError, match="engine_factory"):
            FaultInjector(FaultPlan.parse("crash@1:r0 rejoin@5:r0"))

    def test_fault_beyond_fleet_raises(self):
        inj = FaultInjector(FaultPlan.parse("crash@0:r7"))
        with pytest.raises(ValueError, match="r7"):
            inj.begin_tick(_StubRouter(n=3))


# ---------------------------------------------------------------------------
# fleet failover on real replicas
# ---------------------------------------------------------------------------
class TestFleetFailover:
    def test_crash_failover_bitwise_exact_across_policies(self, dense):
        """The tentpole invariant: under crash-of-1-of-3, every surviving
        request's tokens are bitwise the fault-free run's, for every router
        policy, and every rid is accounted (nothing silently lost)."""
        cfg, params = dense
        ref_router = rt_mod.Router(_engines(params, cfg, 3),
                                   rt_mod.RouterConfig(policy="immune"))
        ref_router.run(_fleet(cfg))
        ref = _tokens_by_rid(ref_router)
        plan = "crash@5:r1"
        for policy in rt_mod.POLICIES:
            reqs = _fleet(cfg)
            router = rt_mod.Router(
                _engines(params, cfg, 3), rt_mod.RouterConfig(policy=policy),
                injector=FaultInjector(FaultPlan.parse(plan)))
            s = router.run(reqs)
            assert s["deaths"] == 1 and s["health"][1] == rt_mod.DEAD
            got = _tokens_by_rid(router)
            assert got == {rid: ref[rid] for rid in got}, policy
            assert s["completed"] + s["shed"] + s["rejected"] + s["failed"] \
                == len(reqs)
            assert s["unserved"] == 0
            fleet = router.engines + router.fallen
            accounted = ({r.rid for r in router.completed}
                         | {r.rid for e in fleet for r in e.shed}
                         | {r.rid for e in fleet for r in e.rejected}
                         | {r.rid for r in router.failed})
            assert accounted == {r.rid for r in reqs}, policy

    def test_failover_replays_in_flight_request(self, dense):
        """A request mid-decode on the crashed replica is evacuated and
        finishes on a survivor with replayed tokens charged, its original
        arrival preserved, and one retry spent."""
        cfg, params = dense
        reqs = _fleet(cfg)
        router = rt_mod.Router(
            _engines(params, cfg, 3), rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("crash@5:r0")))
        s = router.run(reqs)
        assert s["replaced_requests"] > 0
        replaced = [r for r in router.completed
                    if r.rid in router.replaced_rids]
        assert replaced, "no evacuated request completed"
        by_rid = {r.rid: r for r in reqs}
        for r in replaced:
            assert r.retries == 1
            assert r.arrival == by_rid[r.rid].arrival   # original, not requeue
        assert s["retries"] >= len(replaced)
        assert s["recovery_ticks"] > 0

    def test_rejoin_restores_capacity_and_rewarms_cache(self, dense):
        """A crashed replica rejoining cold returns to full health, takes
        placements again, and prefix-affinity traffic rewarms its pinned
        prefix cache from live traffic."""
        cfg, params = dense
        reqs, spec = traces.failover_fleet_trace(
            cfg, replicas=3, num_requests=18, tenants=3, prefix_len=32,
            suffix_lens=(4,), decode_lens=(6,), burst_every=4, burst_size=3)
        router = rt_mod.Router(
            _engines(params, cfg, 3), rt_mod.RouterConfig(policy="immune"),
            injector=FaultInjector(
                FaultPlan.parse(spec),
                engine_factory=lambda: _engines(params, cfg, 1)[0]))
        s = router.run(reqs)
        assert s["deaths"] == 1 and s["rejoins"] == 1
        assert s["health"] == [rt_mod.HEALTHY] * 3
        assert s["failed"] == 0 and s["unserved"] == 0
        assert router.engines[1].alloc.pages_pinned > 0   # rewarmed
        assert len(router.fallen) == 1                    # old process kept
        # the fallen replica's pre-crash completions stay in the books
        assert s["completed"] == len(reqs) - s["shed"] - s["rejected"]

    def test_straggler_and_stall_survive_without_failover(self, dense):
        """A slowdown or a stall shorter than dead_after flaps health but
        never kills the replica; tokens stay bitwise the fault-free run's."""
        cfg, params = dense
        ref_router = rt_mod.Router(_engines(params, cfg, 3),
                                   rt_mod.RouterConfig(policy="immune"))
        ref_router.run(_fleet(cfg))
        ref = _tokens_by_rid(ref_router)
        reqs = _fleet(cfg)
        router = rt_mod.Router(
            _engines(params, cfg, 3), rt_mod.RouterConfig(policy="immune"),
            injector=FaultInjector(
                FaultPlan.parse("slow@2+8:r0:x3 stall@4+3:r2")))
        s = router.run(reqs)
        assert s["deaths"] == 0
        assert s["health"] == [rt_mod.HEALTHY] * 3
        assert _tokens_by_rid(router) == ref
        assert s["completed"] + s["shed"] + s["rejected"] == len(reqs)

    def test_pressure_shock_conserves_pages_and_parity(self, dense):
        """A transient page seizure shrinks the pool (conservation invariant
        intact), is fully restored, and never changes emitted tokens."""
        cfg, params = dense
        ref_router = rt_mod.Router(_engines(params, cfg, 3),
                                   rt_mod.RouterConfig(policy="immune"))
        ref_router.run(_fleet(cfg))
        ref = _tokens_by_rid(ref_router)
        reqs = _fleet(cfg)
        router = rt_mod.Router(
            _engines(params, cfg, 3), rt_mod.RouterConfig(policy="immune"),
            injector=FaultInjector(FaultPlan.parse("pressure@3+6:r0:p3")))
        s = router.run(reqs)
        assert s["faults"]["pressure_shocks"] == 1
        assert _tokens_by_rid(router) == ref
        for eng in router.engines:
            a = eng.alloc
            live = {p for sl in range(a.num_slots) for p in a.owned(sl)}
            assert len(a._free) + len(live) + a.pages_pinned \
                == a.usable_pages
            assert a.pages_seized == 0       # shock expired: fully restored

    def test_retry_budget_exhaustion_fails_terminally(self, dense):
        """With a zero retry budget, evacuated requests terminate with
        finish_reason="failed" — counted in demand (goodput denominator),
        never silently lost."""
        cfg, params = dense
        reqs = _fleet(cfg)
        router = rt_mod.Router(
            _engines(params, cfg, 3),
            rt_mod.RouterConfig(policy="rr", max_retries=0),
            injector=FaultInjector(FaultPlan.parse("crash@5:r0")))
        s = router.run(reqs)
        assert s["failed"] > 0
        assert all(r.finish_reason == "failed" for r in router.failed)
        assert s["completed"] + s["shed"] + s["rejected"] + s["failed"] \
            == len(reqs)
        # failed requests count against goodput
        assert s["goodput"] < 1.0

    def test_graceful_degradation_sheds_marked_classes_first(self, dense):
        """While a replica is down, survivors shed degrade_classes traffic
        (anergy from the fleet-stress stimulus) while the other classes keep
        completing — brown-out by priority, not at random."""
        cfg, params = dense
        reqs = _fleet(cfg, num_requests=18, hot_frac=0.34, burst_every=3)
        router = rt_mod.Router(
            _engines(params, cfg, 3),
            rt_mod.RouterConfig(policy="immune", degrade_classes=(2,)),
            injector=FaultInjector(FaultPlan.parse("crash@4:r1")))
        s = router.run(reqs)
        assert s["deaths"] == 1
        shed = [r for e in router.engines + router.fallen for r in e.shed]
        assert shed, "degradation never shed anything"
        assert all(r.rclass == 2 for r in shed)
        done_classes = {r.rclass for r in router.completed}
        assert {0, 1} <= done_classes
