"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.grid_step import grid_step, grid_step_ref
from repro.kernels.moe_gmm import gmm_ref, moe_gmm

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("b,h,hk,s,d", [
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 512, 128),    # MQA, larger head_dim
    (2, 4, 4, 128, 32),     # MHA, small
    (1, 2, 2, 384, 64),     # non-power-of-two kv blocks (384 = 3*128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, hk, s, d, dtype, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hk, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hk, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bkv=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("e,c,d,f", [(8, 64, 32, 64), (4, 128, 128, 256),
                                     (6, 32, 64, 32), (3, 96, 96, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_matches_ref(e, c, d, f, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (e, c, d), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f), dtype)
    sizes = jax.random.randint(jax.random.fold_in(key, 2), (e,), 0, c + 1)
    xm = jnp.where(jnp.arange(c)[None, :, None] < sizes[:, None, None], x, 0)
    out = moe_gmm(xm, w, sizes, bc=32, bf=32, bd=32, interpret=True)
    ref = gmm_ref(xm, w, sizes)
    tol = 2e-1 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


def test_moe_gmm_empty_groups_are_zero():
    e, c, d, f = 4, 32, 16, 16
    x = jnp.ones((e, c, d))
    w = jnp.ones((e, d, f))
    sizes = jnp.asarray([0, 32, 0, 16])
    out = moe_gmm(x * (jnp.arange(c)[None, :, None] < sizes[:, None, None]),
                  w, sizes, bc=16, bf=16, bd=16, interpret=True)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


@pytest.mark.parametrize("h,w,band", [(16, 32, 8), (40, 32, 8), (33, 16, 8),
                                      (64, 128, 16), (8, 256, 8)])
def test_grid_step_matches_ref(h, w, band):
    key = jax.random.PRNGKey(2)
    lab = jax.random.randint(key, (h, w), 0, 50, jnp.int32)
    cond = (jax.random.uniform(jax.random.fold_in(key, 1), (h, w)) < 0.6) \
        .astype(jnp.int32)
    lab = lab * cond
    out = grid_step(lab, cond, band=band, interpret=True)
    ref = grid_step_ref(lab, cond)
    assert bool(jnp.all(out == ref))


def test_grid_step_reaches_fixpoint_like_components():
    """Iterating the kernel floods each conductor component with its max label."""
    cond = jnp.zeros((16, 16), jnp.int32).at[2, 2:10].set(1).at[8:14, 5].set(1)
    lab = jnp.zeros((16, 16), jnp.int32).at[2, 3].set(7).at[10, 5].set(9)
    for _ in range(20):
        lab = grid_step(lab, cond, interpret=True)
    assert bool(jnp.all(jnp.where(cond.at[8:14, 5].set(0) == 1, lab == 7, True)))
    assert bool(jnp.all(jnp.where(jnp.zeros_like(cond).at[8:14, 5].set(1) == 1,
                                  lab == 9, True)))
