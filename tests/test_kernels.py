"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.grid_step import grid_step, grid_step_ref
from repro.kernels.moe_gmm import gmm_ref, moe_gmm
from repro.kernels.paged_attention import paged_attention, paged_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _paged_fixture(key, b, h, hk, d, num_pages, page, maxp, seed):
    """Random page pools + a valid block table with ragged per-row page counts
    (lengths anywhere in [1, maxp*page], pages covering exactly ceil(len/page))."""
    q = jax.random.normal(key, (b, h, d), jnp.float32)
    kp = jax.random.normal(jax.random.fold_in(key, 1),
                           (num_pages, page, hk, d), jnp.float32)
    vp = jax.random.normal(jax.random.fold_in(key, 2),
                           (num_pages, page, hk, d), jnp.float32)
    rng = np.random.default_rng(seed)
    lengths = rng.integers(1, maxp * page + 1, size=b)
    free = list(rng.permutation(np.arange(1, num_pages)))   # page 0 = null
    table = np.zeros((b, maxp), np.int32)
    for i in range(b):
        for j in range(-(-int(lengths[i]) // page)):
            table[i, j] = free.pop()
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lengths, jnp.int32)


@pytest.mark.parametrize("b,h,hk,d", [
    (3, 4, 4, 32),      # MHA
    (2, 8, 2, 64),      # GQA 4:1
    (2, 8, 1, 128),     # MQA
])
@pytest.mark.parametrize("page,maxp", [(8, 4), (16, 3)])
@pytest.mark.parametrize("seed", [0, 1])
def test_paged_attention_matches_ref(b, h, hk, d, page, maxp, seed):
    """Block-table gather inside the kernel == dense-gather oracle to <= 1e-5,
    across GQA head ratios and ragged page counts."""
    key = jax.random.PRNGKey(seed)
    q, kp, vp, table, lengths = _paged_fixture(
        key, b, h, hk, d, num_pages=b * maxp + 1, page=page, maxp=maxp,
        seed=seed)
    out = paged_attention(q, kp, vp, table, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_paged_attention_bf16(bdims=(2, 8, 2, 64)):
    b, h, hk, d = bdims
    key = jax.random.PRNGKey(3)
    q, kp, vp, table, lengths = _paged_fixture(
        key, b, h, hk, d, num_pages=b * 4 + 1, page=8, maxp=4, seed=3)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    out = paged_attention(q, kp, vp, table, lengths, interpret=True)
    ref = paged_attention_ref(q, kp, vp, table, lengths)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=5e-2, atol=5e-2)


def test_paged_attention_ignores_dirty_null_page():
    """Unmapped table entries point at page 0; its contents must never leak
    into the output (the engine uses it as the write trash can)."""
    b, h, hk, d, page, maxp = 2, 4, 2, 32, 8, 3
    key = jax.random.PRNGKey(5)
    q, kp, vp, table, lengths = _paged_fixture(
        key, b, h, hk, d, num_pages=b * maxp + 1, page=page, maxp=maxp, seed=5)
    clean = paged_attention(q, kp, vp, table, lengths, interpret=True)
    dirty_k = kp.at[0].set(1e4)
    dirty_v = vp.at[0].set(-1e4)
    dirty = paged_attention(q, dirty_k, dirty_v, table, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


@pytest.mark.parametrize("b,h,hk,s,d", [
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 512, 128),    # MQA, larger head_dim
    (2, 4, 4, 128, 32),     # MHA, small
    (1, 2, 2, 384, 64),     # non-power-of-two kv blocks (384 = 3*128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, h, hk, s, d, dtype, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, hk, s, d), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, hk, s, d), dtype)
    out = flash_attention(q, k, v, causal=causal, bq=64, bkv=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-5
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("e,c,d,f", [(8, 64, 32, 64), (4, 128, 128, 256),
                                     (6, 32, 64, 32), (3, 96, 96, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm_matches_ref(e, c, d, f, dtype):
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (e, c, d), dtype)
    w = jax.random.normal(jax.random.fold_in(key, 1), (e, d, f), dtype)
    sizes = jax.random.randint(jax.random.fold_in(key, 2), (e,), 0, c + 1)
    xm = jnp.where(jnp.arange(c)[None, :, None] < sizes[:, None, None], x, 0)
    out = moe_gmm(xm, w, sizes, bc=32, bf=32, bd=32, interpret=True)
    ref = gmm_ref(xm, w, sizes)
    tol = 2e-1 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


def test_moe_gmm_empty_groups_are_zero():
    e, c, d, f = 4, 32, 16, 16
    x = jnp.ones((e, c, d))
    w = jnp.ones((e, d, f))
    sizes = jnp.asarray([0, 32, 0, 16])
    out = moe_gmm(x * (jnp.arange(c)[None, :, None] < sizes[:, None, None]),
                  w, sizes, bc=16, bf=16, bd=16, interpret=True)
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


@pytest.mark.parametrize("h,w,band", [(16, 32, 8), (40, 32, 8), (33, 16, 8),
                                      (64, 128, 16), (8, 256, 8)])
def test_grid_step_matches_ref(h, w, band):
    key = jax.random.PRNGKey(2)
    lab = jax.random.randint(key, (h, w), 0, 50, jnp.int32)
    cond = (jax.random.uniform(jax.random.fold_in(key, 1), (h, w)) < 0.6) \
        .astype(jnp.int32)
    lab = lab * cond
    out = grid_step(lab, cond, band=band, interpret=True)
    ref = grid_step_ref(lab, cond)
    assert bool(jnp.all(out == ref))


def test_grid_step_reaches_fixpoint_like_components():
    """Iterating the kernel floods each conductor component with its max label."""
    cond = jnp.zeros((16, 16), jnp.int32).at[2, 2:10].set(1).at[8:14, 5].set(1)
    lab = jnp.zeros((16, 16), jnp.int32).at[2, 3].set(7).at[10, 5].set(9)
    for _ in range(20):
        lab = grid_step(lab, cond, interpret=True)
    assert bool(jnp.all(jnp.where(cond.at[8:14, 5].set(0) == 1, lab == 7, True)))
    assert bool(jnp.all(jnp.where(jnp.zeros_like(cond).at[8:14, 5].set(1) == 1,
                                  lab == 9, True)))
