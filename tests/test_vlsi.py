"""The paper's experiment: agent-based VLSI extraction vs the serial oracle."""
import jax
import numpy as np
import pytest

from repro.core.vlsi import extractor, layout, reference

jax.config.update("jax_platform_name", "cpu")


class TestOracle:
    def test_nand_netlist(self):
        net = reference.extract(layout.nand_layout())
        assert len(net.fets) == 4
        pfets = [f for f in net.fets if f.pol == "p"]
        nfets = [f for f in net.fets if f.pol == "n"]
        assert len(pfets) == 2 and len(nfets) == 2
        # parallel pull-ups share one node; series pull-downs chain
        p_nodes = [n for f in pfets for n in f.sd]
        assert len(set(p_nodes)) == 3, "2 parallel PFETs must share a drain node"
        assert len(net.equivs) == 7

    def test_dff_tile_counts(self):
        net = reference.extract(layout.dff_layout())
        assert len(net.fets) == 32
        assert len(net.equivs) == 56

    def test_inverter(self):
        g = layout._with_margin(layout.inverter_cell())
        net = reference.extract(g)
        assert len(net.fets) == 2
        assert {f.pol for f in net.fets} == {"n", "p"}


class TestAgentExtraction:
    @pytest.mark.parametrize("seed", [1, 2])
    def test_nand_equivalent_to_oracle(self, seed):
        lay = layout.nand_layout()
        oracle = reference.extract(lay)
        grid, steps, _ = extractor.run_extraction(lay, n_agents=64, seed=seed,
                                                  max_steps=4000)
        assert steps < 4000, "extraction did not terminate"
        sim = extractor.harvest(grid, lay)
        ok, msg = extractor.netlists_equivalent(sim, oracle)
        assert ok, msg

    def test_more_agents_do_not_break_correctness(self):
        lay = layout.nand_layout()
        oracle = reference.extract(lay)
        grid, steps, _ = extractor.run_extraction(lay, n_agents=192, seed=0,
                                                  max_steps=4000)
        sim = extractor.harvest(grid, lay)
        ok, msg = extractor.netlists_equivalent(sim, oracle)
        assert ok, msg

    def test_redundant_statements_are_emitted_and_deduplicated(self):
        """Paper: multiple contacts between one node pair produce redundant
        equivalence statements; the harvester deduplicates them by region."""
        # 64 agents on the same grid shape as the seed tests above: reuses their
        # compiled extractor instead of paying a fresh multi-second XLA compile
        lay = layout.nand_layout(double_contacts=True)
        grid, _, _ = extractor.run_extraction(lay, n_agents=64, seed=0,
                                              max_steps=4000)
        sim = extractor.harvest(grid, lay)
        # the two disjoint input contacts hit the same (m1, poly) node pairs
        assert len(sim.equivs) < 9

    def test_population_dynamics_shape(self):
        """Fig. 3 qualitative shape: finder crash, labeller spike, propagator
        steady state."""
        lay = layout.nand_layout()
        _, steps, pops = extractor.run_extraction(lay, n_agents=96, seed=0,
                                                  max_steps=4000, record=True)
        pops = np.asarray(pops)
        finders = pops[:, extractor.FINDER]
        labellers = pops[:, extractor.LABELLER]
        props = pops[:, extractor.PROPAGATOR]
        late = min(steps, 3999) - 1
        # finders crash (possibly after the paper's "second generation" rebound)
        assert finders[late] < finders[:30].max() / 4
        assert labellers[:50].max() >= labellers[0], "labeller spike missing"
        assert labellers[late] == 0, "labellers must die out"
        assert props[late] == 96, "steady state must be all node propagators"
        assert props[0] < 96 / 2, "propagators cannot dominate at start"


class TestRandomLayouts:
    @pytest.mark.parametrize("seed", [3, 7])
    def test_random_tiling_extracts_correctly(self, seed):
        rng = np.random.default_rng(seed)
        lay = layout.random_layout(rng, rows=1, cols=2)
        oracle = reference.extract(lay)
        grid, steps, _ = extractor.run_extraction(lay, n_agents=96, seed=seed,
                                                  max_steps=5000)
        sim = extractor.harvest(grid, lay)
        ok, msg = extractor.netlists_equivalent(sim, oracle)
        assert ok, msg
