"""Minimal stand-in for ``hypothesis`` when the real package is unavailable.

The test environment declared in pyproject.toml includes hypothesis (CI installs
it and gets the real shrinking engine); offline/airgapped environments may not
have it. Rather than losing two whole test modules to a collection error,
``conftest.py`` installs this fallback, which implements the small slice of the
API our property tests use:

  * ``@given(...)`` with positional or keyword strategies
  * ``settings(deadline=..., max_examples=...)`` as a decorator (or reusable
    decorator instance)
  * strategies: ``integers``, ``floats``, ``booleans``, ``sampled_from``,
    ``lists``

Draws are deterministic per test (seeded from the test's qualname) so failures
reproduce; the first example of every range strategy is its minimum and the
second its maximum, so boundary cases are always exercised. No shrinking, no
database — this is a fallback, not a replacement.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib

__version__ = "0.0-fallback"


class settings:
    """Decorator (class instance) recording example-count / deadline knobs."""

    def __init__(self, deadline=None, max_examples: int = 100, **_ignored):
        self.deadline = deadline
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._hyp_settings = self
        return fn


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random, index: int):
        return self._draw(rng, index)


def integers(min_value: int, max_value: int) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def floats(min_value: float, max_value: float, **_ignored) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def booleans() -> _Strategy:
    return _Strategy(lambda rng, i: (False, True)[i] if i < 2
                     else bool(rng.getrandbits(1)))


def sampled_from(options) -> _Strategy:
    options = list(options)
    return _Strategy(lambda rng, i: options[i % len(options)] if i < len(options)
                     else rng.choice(options))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(rng, i):
        if i == 0:
            size = min_size
        elif i == 1:
            size = max_size
        else:
            size = rng.randint(min_size, max_size)
        return [elements.example(rng, 2 + rng.randrange(1 << 16))
                for _ in range(size)]
    return _Strategy(draw)


def given(*pos_strategies, **kw_strategies):
    def decorate(fn):
        sig = inspect.signature(fn)
        names = [n for n in sig.parameters if n != "self"]
        # real hypothesis binds positional strategies to the RIGHTMOST params
        # (leftward ones stay free for pytest fixtures) — match that
        mapping = dict(zip(names[len(names) - len(pos_strategies):],
                           pos_strategies))
        mapping.update(kw_strategies)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_hyp_settings", None) or settings()
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(conf.max_examples):
                drawn = {k: s.example(rng, i) for k, s in mapping.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i}): {drawn!r}") from e

        # hide the strategy-filled params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=[
            p for n, p in sig.parameters.items() if n not in mapping])
        return wrapper
    return decorate


def install(mod: types.ModuleType | None = None) -> types.ModuleType:
    """Register this module as ``hypothesis`` (+ ``hypothesis.strategies``).

    ``mod`` is the loaded module object; pass it explicitly when loading via a
    spec that never touched ``sys.modules`` (registration happens only here,
    after a successful exec, so a broken load can't poison later imports).
    """
    if mod is None:
        mod = sys.modules[__name__]
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(strategies, name, getattr(mod, name))
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
    return mod
