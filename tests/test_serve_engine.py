"""Continuous-batching engine: decode-parity oracle + admission behavior.

The correctness anchor is *token parity*: a request served by the engine —
prefilled into an arbitrary slot mid-stream, decoded alongside unrelated
sequences at other depths, retired, its slot compacted and reused — must emit
exactly the tokens that one-shot ``serve.decode.generate`` produces for the
same prompt and params. That pins slot insertion, per-slot positions (rope +
causal masks), compaction, and cross-slot isolation in one observable.

MoE runs at the *default* capacity factor on purpose: the engine's decode tick
bumps capacity to be dropless (a garbage lane from an empty slot must never
displace a real request's token at an expert's capacity limit), and prefill
is a batch-of-1 call identical to the oracle's — so parity must hold with no
capacity pinning at all.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import decode
from repro.serve import engine as eng_mod

jax.config.update("jax_platform_name", "cpu")


def _smoke_cfg(arch):
    return configs.get_config(arch).smoke()


def _params(cfg):
    return model.init_params(jax.random.PRNGKey(0), cfg)


def _bias(cfg):
    return (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)


def _make_requests(cfg, n, seed=0, prompt_lens=(6, 10), steps=(5, 8),
                   stagger=1):
    """Staggered heterogeneous requests; two prompt-length buckets bound the
    number of prefill shapes the engine compiles."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = prompt_lens[rid % len(prompt_lens)]
        req = eng_mod.Request(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=steps[rid % len(steps)],
            rclass=rid % 2,
            arrival=rid * stagger)
        reqs.append(eng_mod.attach_modality_inputs(req, cfg, rng))
    return reqs


def _oracle_tokens(params, cfg, req, max_cache, bias):
    # req.prompts() is exactly what the engine prefills — same arrays, no copy
    toks, _ = decode.generate(params, cfg, req.prompts(), max_cache=max_cache,
                              steps=req.max_new_tokens, router_bias=bias)
    return [int(t) for t in np.asarray(toks[0])]


class TestDecodeParity:
    """Engine output == one-shot generate, token for token, per family."""

    def test_dense_staggered_trace_token_identical(self):
        """The acceptance trace: >= 8 staggered requests through 3 slots, so
        slots are reused and every admission is mid-stream."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="immune",
                                    num_classes=2, latency_budget=64.0)
        reqs = _make_requests(cfg, 9)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=500)
        assert stats["completed"] == 9 and stats["shed"] == 0
        # admissions actually interleaved with other slots' decodes
        assert stats["mid_stream_admissions"] >= 6
        # slots were reused (9 requests > 3 slots) and compacted afterwards
        assert all(r is None for r in eng.slots)
        assert not bool(eng.active.any())
        assert np.asarray(eng.pool["pos"]).tolist() == [0, 0, 0]
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, f"request {req.rid} diverged"

    @pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "paligemma-3b",
                                      "musicgen-medium"])
    def test_moe_vlm_audio_token_identical(self, arch):
        cfg = _smoke_cfg(arch)
        params = _params(cfg)
        bias = _bias(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        reqs = _make_requests(cfg, 4, seed=1, steps=(4, 6))
        eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4
        assert stats["mid_stream_admissions"] >= 1
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, bias)
            assert req.out_tokens == oracle, f"{arch} request {req.rid} diverged"


class TestEngineMechanics:
    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_eos_early_stop(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        [probe] = _make_requests(cfg, 1, steps=(6,))
        eng = eng_mod.Engine(params, cfg, ecfg)
        eng.run([probe], max_ticks=50)
        assert len(probe.out_tokens) == 6
        # rerun with eos = the 3rd emitted token: output must stop right there
        [again] = _make_requests(cfg, 1, steps=(6,))
        again.eos_id = probe.out_tokens[2]
        eng2 = eng_mod.Engine(params, cfg, ecfg)
        eng2.run([again], max_ticks=50)
        assert again.out_tokens == probe.out_tokens[:3]

    def test_single_token_request_retires_at_admission_tick(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        [req] = _make_requests(cfg, 1, steps=(1,))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run([req], max_ticks=20)
        assert stats["completed"] == 1
        assert len(req.out_tokens) == 1
        assert req.finish_tick == req.admit_tick

    def test_submit_rejects_oversized_request(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=16)
        eng = eng_mod.Engine(params, cfg, ecfg)
        [req] = _make_requests(cfg, 1, prompt_lens=(12,), steps=(8,))
        with pytest.raises(ValueError, match="max_cache"):
            eng.submit(req)


class TestImmuneAdmission:
    """Unit-level behavior of the admission controller (no model involved)."""

    def _ecfg(self, **kw):
        base = dict(num_slots=4, max_cache=64, policy="immune", num_classes=3,
                    latency_budget=10.0)
        base.update(kw)
        return eng_mod.EngineConfig(**base)

    def test_burst_throttles_then_recovers(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        none = np.zeros(3)
        assert not adm.throttled()            # fast path: bursts admit freely
        for _ in range(4):                    # sustained full-pool admission
            adm.end_tick(admitted=4, queue_len=10, queued_demand=none,
                         predicted_cost=none)
        assert adm.throttled(), "delayed suppression never engaged"
        for _ in range(60):                   # quiet: suppressor drains response
            adm.end_tick(admitted=0, queue_len=0, queued_demand=none,
                         predicted_cost=none)
        assert not adm.throttled(), "throttle never released"

    def test_blown_budget_sheds_then_pressure_drop_revives(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        demand = np.asarray([1.0, 0.0, 1.0])
        cost = np.asarray([2.0, 2.0, 50.0])   # class 2 cannot meet the budget
        for _ in range(6):                    # high pressure: no IL-2
            adm.observe_completion(0, cost=2.0, latency=3.0)
            adm.end_tick(admitted=1, queue_len=20, queued_demand=demand,
                         predicted_cost=cost)
        assert not adm.admissible(2), "abusive class never shed"
        assert adm.admissible(0) and adm.admissible(1), \
            "healthy classes shed alongside the abusive one"
        for _ in range(20):                   # pressure drops: IL-2 revives
            adm.end_tick(admitted=0, queue_len=0, queued_demand=np.zeros(3),
                         predicted_cost=cost)
        assert adm.admissible(2), "anergy is supposed to be reversible"

    def test_memory_tracks_per_class_cost(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        for _ in range(30):
            adm.observe_completion(0, cost=4.0, latency=5.0)
            adm.observe_completion(1, cost=40.0, latency=45.0)
        assert abs(adm.remembered_cost(0) - 4.0) < 0.5
        assert abs(adm.remembered_cost(1) - 40.0) < 5.0
        assert adm.remembered_cost(2) == 0.0  # untouched class unchanged


class TestImmuneVsFifo:
    def test_immune_tail_no_worse_than_fifo_under_bursts(self):
        """The benchmark's acceptance property, in-suite: bursty heterogeneous
        traffic, identical trace, immune p99 <= FIFO p99 (and goodput at least
        as high) — the anticipation + shedding loop protecting the tail."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        stats = {}
        for policy in ("fifo", "immune"):
            ecfg = eng_mod.EngineConfig(num_slots=4, max_cache=64,
                                        policy=policy, num_classes=3,
                                        latency_budget=24.0)
            trace = eng_mod.synthetic_trace(cfg, num_requests=24, seed=0)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats[policy] = eng.run(trace, max_ticks=1200)
        assert stats["fifo"]["completed"] == 24
        imm, fifo = stats["immune"], stats["fifo"]
        assert imm["p99_latency"] <= fifo["p99_latency"], (imm, fifo)
        assert imm["goodput"] >= fifo["goodput"], (imm, fifo)
