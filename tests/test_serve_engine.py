"""Continuous-batching engine: decode-parity oracle + admission behavior.

The correctness anchor is *token parity*: a request served by the engine —
prefilled into an arbitrary slot mid-stream (one-shot or chunk by chunk into
its block-table pages), decoded alongside unrelated sequences at other depths,
retired, its pages freed and reused — must emit exactly the tokens that
one-shot ``serve.decode.generate`` produces for the same prompt and params.
That pins page scatter/gather, per-slot positions (rope + causal masks),
chunked-prefill state threading, and cross-slot isolation in one observable.

MoE runs at the *default* capacity factor on purpose: the engine's decode tick
bumps capacity to be dropless (a garbage lane from an empty slot must never
displace a real request's token at an expert's capacity limit), and one-shot
prefill is a batch-of-1 call identical to the oracle's — so parity must hold
with no capacity pinning at all. The *chunked* MoE case pins capacity to
dropless on both sides instead: expert capacity is per-call, so a chunked
prefill at finite capacity could legitimately drop tokens the one-shot oracle
keeps — parity there is only defined dropless.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import decode, traces
from repro.serve import engine as eng_mod
from repro.serve.api import SamplingParams, ServeRequest

jax.config.update("jax_platform_name", "cpu")


def _smoke_cfg(arch):
    return configs.get_config(arch).smoke()


def _params(cfg):
    return model.init_params(jax.random.PRNGKey(0), cfg)


def _bias(cfg):
    return (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)


def _make_requests(cfg, n, seed=0, prompt_lens=(6, 10), steps=(5, 8),
                   stagger=1):
    """Staggered heterogeneous requests; two prompt-length buckets bound the
    number of prefill shapes the engine compiles."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = prompt_lens[rid % len(prompt_lens)]
        req = ServeRequest(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            params=SamplingParams(max_new_tokens=steps[rid % len(steps)]),
            rclass=rid % 2,
            arrival=rid * stagger)
        reqs.append(traces.attach_modality_inputs(req, cfg, rng))
    return reqs


def _oracle_tokens(params, cfg, req, max_cache, bias):
    # req.prompts() is exactly what the engine prefills — same arrays, no copy
    toks, _ = decode.generate(params, cfg, req.prompts(), max_cache=max_cache,
                              steps=req.max_new_tokens, router_bias=bias)
    return [int(t) for t in np.asarray(toks[0])]


class TestDecodeParity:
    """Engine output == one-shot generate, token for token, per family."""

    def test_dense_staggered_trace_token_identical(self):
        """The acceptance trace: >= 8 staggered requests through 3 slots, so
        slots are reused and every admission is mid-stream."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="immune",
                                    num_classes=2, latency_budget=64.0)
        reqs = _make_requests(cfg, 9)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=500)
        assert stats["completed"] == 9 and stats["shed"] == 0
        # admissions actually interleaved with other slots' decodes
        assert stats["mid_stream_admissions"] >= 6
        # slots were reused (9 requests > 3 slots) and drained clean: no live
        # pages, every page back on the free list, positions reset
        assert all(r is None for r in eng.slots)
        assert not bool(eng.active.any())
        assert np.asarray(eng.pool["pos"]).tolist() == [0, 0, 0]
        assert stats["pages_in_use"] == 0
        assert 0 < stats["pages_hw"] <= stats["pages_budget"]
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, f"request {req.rid} diverged"

    def test_chunked_prefill_mid_stream_token_identical(self):
        """Long prompts land chunk by chunk (one per tick) while other slots
        keep decoding — and the tokens still match one-shot ``generate``
        exactly. The 24-token prompts take 3 chunks each, so every multi-chunk
        prefill overlaps live decodes."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="immune",
                                    num_classes=2, latency_budget=64.0,
                                    prefill_chunk=8)
        reqs = _make_requests(cfg, 8, prompt_lens=(24, 10))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=500)
        assert stats["completed"] == 8 and stats["shed"] == 0
        # 4 long prompts x 3 chunks + 4 short x 2 chunks = 20 chunk calls
        assert stats["chunked_prefill_chunks"] == 20
        assert stats["mid_stream_admissions"] >= 5
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, \
                f"request {req.rid} diverged after chunked prefill"

    def test_chunked_prefill_moe_dropless_token_identical(self):
        """Chunked MoE prefill at *dropless* capacity (pinned on both engine
        and oracle: capacity is per-call, so finite-capacity drops are not
        comparable across chunkings)."""
        cfg = dataclasses.replace(_smoke_cfg("granite-moe-3b-a800m"),
                                  capacity_factor=8.0)
        params = _params(cfg)
        bias = _bias(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo",
                                    prefill_chunk=8)
        reqs = _make_requests(cfg, 4, seed=1, prompt_lens=(16, 8), steps=(4, 6))
        eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4
        assert stats["chunked_prefill_chunks"] == 6
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, bias)
            assert req.out_tokens == oracle, f"moe request {req.rid} diverged"

    def test_recurrent_chunked_prefill_token_identical(self):
        """Position-free recurrent config (mamba2): chunked prefill resumes the
        SSD recurrence + conv tail across chunks; aligned lengths make it
        bitwise-identical to the one-shot oracle."""
        cfg = _smoke_cfg("mamba2-130m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo",
                                    prefill_chunk=8)
        reqs = _make_requests(cfg, 4, seed=1, prompt_lens=(16, 8), steps=(4, 6))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4
        assert stats["chunked_prefill_chunks"] == 6
        assert stats["mid_stream_admissions"] >= 1
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, f"ssm request {req.rid} diverged"

    @pytest.mark.parametrize("arch", ["granite-moe-3b-a800m", "paligemma-3b",
                                      "musicgen-medium"])
    def test_moe_vlm_audio_token_identical(self, arch):
        cfg = _smoke_cfg(arch)
        params = _params(cfg)
        bias = _bias(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        reqs = _make_requests(cfg, 4, seed=1, steps=(4, 6))
        eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4
        assert stats["mid_stream_admissions"] >= 1
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, bias)
            assert req.out_tokens == oracle, f"{arch} request {req.rid} diverged"


def _shared_prefix_family(cfg, seed=0):
    """A crafted shared-prefix request family: a 48-token donor, a follower
    whose prompt is a strict prefix of it (full-page hits + a partial-page hit
    that must CoW-fork), a same-prompt twin, and two requests behind a second
    prefix — every sharing path in one trace."""
    rng = np.random.default_rng(seed)
    donor = rng.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)
    def mk(rid, tokens, max_new_tokens, arrival):
        return ServeRequest(rid=rid, tokens=tokens, arrival=arrival,
                            params=SamplingParams(max_new_tokens=max_new_tokens))
    return [
        mk(rid=0, tokens=donor.copy(), max_new_tokens=12, arrival=0),
        # donor[:40]: 2 full-page hits + partial (page 2, 7 tokens) -> CoW
        mk(rid=1, tokens=donor[:40].copy(), max_new_tokens=6, arrival=8),
        # identical prompt: 2 full-page hits + partial (page 2, 15) -> CoW
        mk(rid=2, tokens=donor.copy(), max_new_tokens=5, arrival=10),
        mk(rid=3, tokens=np.concatenate([other, rng.integers(
            0, cfg.vocab_size, size=6).astype(np.int32)]),
           max_new_tokens=6, arrival=12),
        mk(rid=4, tokens=np.concatenate([other, rng.integers(
            0, cfg.vocab_size, size=9).astype(np.int32)]),
           max_new_tokens=5, arrival=20),
    ]


class TestPrefixSharing:
    """Refcounted prefix sharing: adopted pages and CoW forks must be invisible
    in the tokens (bitwise the one-shot oracle's) and visible in the stats."""

    def test_shared_prefix_admission_token_identical(self):
        """System-prompt traffic through sharing + batched prefill streams:
        full-page hits skip their prefill entirely, and every request still
        emits exactly the one-shot oracle's tokens."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=4, max_cache=64, policy="fifo",
                                    prefill_chunk=8, prefill_streams=2)
        reqs = traces.shared_prefix_trace(cfg, num_requests=10,
                                           num_prefixes=2, prefix_len=32,
                                           suffix_lens=(4, 8),
                                           decode_lens=(6, 10),
                                           arrival_every=2)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=500)
        assert stats["completed"] == 10
        # the sharing actually happened: followers adopted the two full
        # prefix pages instead of re-prefilling 32 positions each
        assert stats["shared_pages_adopted"] >= 8
        assert stats["prefill_positions_skipped"] >= 100
        assert stats["prefix_hit_rate"] > 0
        assert stats["prefill_batch_calls"] > 0
        # drained clean: refcounts back to zero, all pages on the free list
        assert stats["pages_in_use"] == 0 and eng.alloc.live_refs() == 0
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, \
                f"request {req.rid} diverged over shared pages"

    def test_cow_fork_partial_page_token_identical(self):
        """Partial-page hits adopt the donor's page and CoW-fork it before the
        tail prefill writes — the copy replaces recomputing the shared
        positions, and the tokens stay bitwise the oracle's."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=64, policy="fifo",
                                    prefill_chunk=8)
        reqs = _shared_prefix_family(cfg)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 5
        assert stats["cow_forks"] >= 1          # rid 1 (tail starts mid-page)
        # rid 2's prompt ends exactly on the shared page boundary: adopted
        # with NO fork — its one write into the page is bitwise a no-op
        assert stats["nowrite_adoptions"] >= 1
        assert stats["shared_pages_adopted"] >= 6
        # rid 1 (40-token prompt, 39 positions shared) lands in ONE tail chunk
        # instead of 5 — the O(unique tokens) prefill claim, measurably
        assert stats["chunked_prefill_chunks"] <= 6 + 1 + 1 + 5 + 2
        assert stats["pages_in_use"] == 0 and eng.alloc.live_refs() == 0
        for req in eng.completed:
            oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
            assert req.out_tokens == oracle, \
                f"request {req.rid} diverged over CoW-forked pages"

    def test_sharing_admits_beyond_free_pool(self):
        """The accounting fix, end to end: at a page budget that worst-case
        fits ONE request, a prefix-twin admits concurrently because it only
        charges its unshared pages — and with sharing off it must wait."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        rng = np.random.default_rng(0)
        prefix = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)

        def reqs():
            return [ServeRequest(
                rid=i, tokens=np.concatenate([prefix, rng.integers(
                    0, cfg.vocab_size, size=4).astype(np.int32)]),
                params=SamplingParams(max_new_tokens=6),
                arrival=(0, 8)[i]) for i in range(2)]

        stats = {}
        for share in (True, False):
            # each request worst-cases 3 pages; 4 usable pages total
            ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=64,
                                        policy="fifo", prefill_chunk=8,
                                        num_pages=5, prefix_sharing=share)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats[share] = eng.run(reqs(), max_ticks=200)
            assert stats[share]["completed"] == 2
            for req in eng.completed:
                oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
                assert req.out_tokens == oracle
        assert stats[True]["concurrency_hw"] == 2, \
            "prefix-hot twin was spuriously deferred despite full-page hits"
        assert stats[False]["concurrency_hw"] == 1, \
            "share-off engine admitted past its page budget"
        assert stats[True]["pages_hw"] <= 4


class TestPallasBackend:
    """attn_backend='pallas_interpret' runs the kernels.paged_attention
    scalar-prefetch kernel on the live decode path; tokens must match the XLA
    gather fallback exactly — including slots decoding over shared and
    CoW-forked pages — across GQA and MHA head layouts."""

    @pytest.mark.parametrize("kv_heads", [2, 4])  # GQA (4/2) and MHA (4/4)
    def test_engine_decode_token_identical_vs_xla(self, kv_heads):
        cfg = dataclasses.replace(_smoke_cfg("smollm-360m"),
                                  num_kv_heads=kv_heads)
        params = _params(cfg)
        outs = {}
        for backend in ("xla", "pallas_interpret"):
            ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=64,
                                        policy="fifo", prefill_chunk=8,
                                        attn_backend=backend)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats = eng.run(_shared_prefix_family(cfg), max_ticks=300)
            assert stats["completed"] == 5
            assert stats["cow_forks"] >= 1       # decode covered forked pages
            assert stats["nowrite_adoptions"] >= 1   # and no-write-shared ones
            outs[backend] = {r.rid: r.out_tokens for r in eng.completed}
            for req in eng.completed:
                oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
                assert req.out_tokens == oracle, \
                    f"[{backend}] request {req.rid} diverged from the oracle"
        assert outs["pallas_interpret"] == outs["xla"]


class TestEngineMechanics:
    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_eos_early_stop(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        [probe] = _make_requests(cfg, 1, steps=(6,))
        eng = eng_mod.Engine(params, cfg, ecfg)
        eng.run([probe], max_ticks=50)
        assert len(probe.out_tokens) == 6
        # rerun with a stop id = the 3rd emitted token: output must stop
        # right there, with the per-request finish reason recorded
        [again] = _make_requests(cfg, 1, steps=(6,))
        again.params = SamplingParams(max_new_tokens=6,
                                      stop=(probe.out_tokens[2],))
        eng2 = eng_mod.Engine(params, cfg, ecfg)
        eng2.run([again], max_ticks=50)
        assert again.out_tokens == probe.out_tokens[:3]
        assert again.finish_reason == "stop"
        assert probe.finish_reason == "length"

    def test_single_token_request_retires_at_admission_tick(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        [req] = _make_requests(cfg, 1, steps=(1,))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run([req], max_ticks=20)
        assert stats["completed"] == 1
        assert len(req.out_tokens) == 1
        assert req.finish_tick == req.admit_tick

    def test_submit_rejects_oversized_request_without_raising(self, dense):
        """A prompt+decode budget that can never fit a slot is shed at submit —
        recorded and counted against goodput — not raised mid-stream: an
        open-loop server drops what it cannot serve, it does not crash."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=16)
        eng = eng_mod.Engine(params, cfg, ecfg)
        big, ok = _make_requests(cfg, 2, prompt_lens=(12, 6), steps=(8, 4))
        eng.submit(big)                       # 12 + 8 = 20 > 16: rejected
        eng.submit(ok)                        # 6 + 4 = 10: queued
        assert eng.rejected == [big] and list(eng.queue) == [ok]
        stats = eng.run([], max_ticks=50)     # drain the queued request
        assert stats["completed"] == 1 and stats["rejected"] == 1
        assert big.out_tokens == []
        # the rejected request still counts as demand in goodput
        assert stats["goodput"] <= 0.5

    def test_out_of_pages_backpressure_defers_then_serves(self, dense):
        """Under worst-case reservation, page exhaustion is backpressure, not
        an error: with pages for only one request in flight, the second waits
        in the queue until the first retires, then completes. Nothing is
        dropped, slots never share pages."""
        cfg, params = dense
        # a pool with fewer pages than one slot's worth: a request that fits
        # max_cache but needs more pages than the whole pool has is rejected at
        # submit (it could never be admitted), not left camping in the queue
        tiny = eng_mod.EngineConfig(num_slots=2, max_cache=32, page_size=16,
                                    num_pages=2, policy="fifo",
                                    admission_mode="reserve")  # 1 usable page
        tiny_eng = eng_mod.Engine(params, cfg, tiny)
        [two_pager] = _make_requests(cfg, 1, prompt_lens=(10,), steps=(8,))
        tiny_eng.submit(two_pager)            # needs 2 pages, pool has 1
        assert tiny_eng.rejected == [two_pager] and not tiny_eng.queue

        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, page_size=16,
                                    num_pages=3, policy="fifo",
                                    admission_mode="reserve")  # 2 usable pages
        eng = eng_mod.Engine(params, cfg, ecfg)
        reqs = _make_requests(cfg, 2, prompt_lens=(10,), steps=(8,))
        stats = eng.run(reqs, max_ticks=100)  # each request needs 2 pages
        assert stats["completed"] == 2 and stats["rejected"] == 0
        assert stats["concurrency_hw"] == 1, \
            "page budget for one request admitted two at once"
        assert stats["pages_hw"] <= 2
        r0, r1 = sorted(eng.completed, key=lambda r: r.rid)
        assert r1.admit_tick >= r0.finish_tick, \
            "second request admitted before the first released its pages"


class TestImmuneAdmission:
    """Unit-level behavior of the admission controller (no model involved)."""

    def _ecfg(self, **kw):
        base = dict(num_slots=4, max_cache=64, policy="immune", num_classes=3,
                    latency_budget=10.0)
        base.update(kw)
        return eng_mod.EngineConfig(**base)

    def test_burst_throttles_then_recovers(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        none = np.zeros(3)
        assert not adm.throttled()            # fast path: bursts admit freely
        for _ in range(4):                    # sustained full-pool admission
            adm.end_tick(admitted=4, queue_len=10, queued_demand=none,
                         predicted_cost=none)
        assert adm.throttled(), "delayed suppression never engaged"
        for _ in range(60):                   # quiet: suppressor drains response
            adm.end_tick(admitted=0, queue_len=0, queued_demand=none,
                         predicted_cost=none)
        assert not adm.throttled(), "throttle never released"

    def test_blown_budget_sheds_then_pressure_drop_revives(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        demand = np.asarray([1.0, 0.0, 1.0])
        cost = np.asarray([2.0, 2.0, 50.0])   # class 2 cannot meet the budget
        for _ in range(6):                    # high pressure: no IL-2
            adm.observe_completion(0, cost=2.0, latency=3.0)
            adm.end_tick(admitted=1, queue_len=20, queued_demand=demand,
                         predicted_cost=cost)
        assert not adm.admissible(2), "abusive class never shed"
        assert adm.admissible(0) and adm.admissible(1), \
            "healthy classes shed alongside the abusive one"
        for _ in range(20):                   # pressure drops: IL-2 revives
            adm.end_tick(admitted=0, queue_len=0, queued_demand=np.zeros(3),
                         predicted_cost=cost)
        assert adm.admissible(2), "anergy is supposed to be reversible"

    def test_memory_tracks_per_class_cost(self):
        adm = eng_mod.ImmuneAdmission(self._ecfg())
        for _ in range(30):
            adm.observe_completion(0, cost=4.0, latency=5.0)
            adm.observe_completion(1, cost=40.0, latency=45.0)
        assert abs(adm.remembered_cost(0) - 4.0) < 0.5
        assert abs(adm.remembered_cost(1) - 40.0) < 5.0
        assert adm.remembered_cost(2) == 0.0  # untouched class unchanged


class TestImmuneVsFifo:
    def test_immune_tail_no_worse_than_fifo_under_bursts(self):
        """The benchmark's acceptance property, in-suite: bursty heterogeneous
        traffic, identical trace, immune p99 <= FIFO p99 (and goodput at least
        as high) — the anticipation + shedding loop protecting the tail."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        stats = {}
        for policy in ("fifo", "immune"):
            ecfg = eng_mod.EngineConfig(num_slots=4, max_cache=64,
                                        policy=policy, num_classes=3,
                                        latency_budget=24.0)
            trace = traces.synthetic_trace(cfg, num_requests=24, seed=0)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats[policy] = eng.run(trace, max_ticks=1200)
        assert stats["fifo"]["completed"] == 24
        imm, fifo = stats["immune"], stats["fifo"]
        assert imm["p99_latency"] <= fifo["p99_latency"], (imm, fifo)
        assert imm["goodput"] >= fifo["goodput"], (imm, fifo)


class TestPreemption:
    """admission_mode="preempt" (the default): admission charges only the
    current footprint, decode-time page exhaustion evicts the lowest-priority
    resident, and an evicted request resumes by replay — re-prefilling its
    original prompt and re-deriving its recorded tokens bitwise."""

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_decode_stall_preempts_and_resumes_token_identical(self, dense):
        """Two requests, pages for one worst case: both admit on their prompt
        footprint, decode growth exhausts the pool, the later arrival (least
        progress) is evicted, re-queued, and finishes token-identical to the
        one-shot oracle — including its chosen-token logprobs."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, page_size=16,
                                    num_pages=3, policy="fifo")  # 2 usable
        reqs = _make_requests(cfg, 2, prompt_lens=(10,), steps=(8,))
        for r in reqs:
            r.params = dataclasses.replace(r.params, logprobs=True)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=200)
        assert stats["completed"] == 2 and stats["rejected"] == 0
        assert stats["concurrency_hw"] == 2, \
            "preempt-mode admission should fill both slots on prompt pages"
        assert stats["preemptions"] >= 1 and stats["preempted_requests"] >= 1
        assert stats["replayed_tokens"] >= 1, \
            "a resumed request re-derives recorded tokens by replay"
        r0, r1 = sorted(eng.completed, key=lambda r: r.rid)
        # deterministic victim: same progress, later arrival, higher rid
        assert r0.preemptions == 0 and r1.preemptions >= 1
        assert r1.requeue_ticks >= 1
        for req in eng.completed:
            probe = ServeRequest(rid=req.rid, tokens=req.tokens,
                                 params=req.params)
            toks, _, lp = decode.generate(params, cfg, probe.prompts(),
                                          max_cache=ecfg.max_cache,
                                          steps=req.max_new_tokens,
                                          return_logprobs=True)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"request {req.rid} diverged across preemption"
            assert len(req.out_logprobs) == len(req.out_tokens)
            np.testing.assert_allclose(
                req.out_logprobs,
                np.asarray(lp[0])[:len(req.out_tokens)], atol=1e-5)

    def test_preempt_admits_strictly_deeper_than_reserve(self, dense):
        """The tentpole A/B on one trace and one page budget: worst-case
        reservation serializes the pair, preemptive admission overlaps them —
        strictly deeper concurrency, everything still completes."""
        cfg, params = dense
        depth = {}
        for mode in ("reserve", "preempt"):
            ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32,
                                        page_size=16, num_pages=3,
                                        policy="fifo", admission_mode=mode)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats = eng.run(_make_requests(cfg, 2, prompt_lens=(10,),
                                           steps=(8,)), max_ticks=200)
            assert stats["completed"] == 2, mode
            depth[mode] = stats["concurrency_hw"]
        assert depth["preempt"] > depth["reserve"], depth

    def test_victim_score_prefers_anergic_then_over_budget(self, dense):
        """Victim ordering is the immune priority inverted: anergic classes
        first, then over-budget, then highest remembered cost; FIFO tiebreak
        by latest arrival then least progress (oldest resident never
        evicted)."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, page_size=16,
                                    policy="immune", num_classes=3,
                                    latency_budget=10.0)
        eng = eng_mod.Engine(params, cfg, ecfg)
        for _ in range(30):   # class 2 becomes the expensive class
            eng.admission.observe_completion(2, cost=40.0, latency=45.0)
        mk = lambda rid, rc, arr: ServeRequest(
            rid=rid, tokens=np.arange(6, dtype=np.int32), rclass=rc,
            arrival=arr)
        cheap, dear = mk(0, 0, 0), mk(1, 2, 0)
        assert eng._victim_score(dear) > eng._victim_score(cheap), \
            "higher remembered class cost should be evicted first"
        eng.tick = 20         # cheap is now over the 10-tick budget
        late = mk(2, 0, 15)
        assert eng._victim_score(cheap) > eng._victim_score(late), \
            "over-budget resident outranks an in-budget one"
        # progress shields: a request with tokens already emitted is kept
        late2 = mk(3, 0, 15)
        late2.out_tokens = [1, 2, 3]
        assert eng._victim_score(late) > eng._victim_score(late2)


class TestPinnedPrefixCache:
    """pin_pages > 0: full-page prefix chains survive refcount zero inside the
    pin budget and returning tenants adopt them instead of re-prefilling."""

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def _trace(self, cfg):
        return traces.returning_tenant_trace(
            cfg, tenants=2, prefix_len=48, suffix_lens=(4,), burst_size=3,
            bursts=2, gap=100, decode_lens=(6,), seed=0)

    def test_returning_tenant_adopts_pinned_pages(self, dense):
        """Pin-on vs pin-off at the same page budget: the second burst adopts
        each tenant's pinned prefix chain (3 pages per tenant) and prefills
        only suffixes — strictly fewer prompt positions computed — and every
        request, pinned-adopt or not, stays token-identical to the oracle."""
        cfg, params = dense
        runs = {}
        for pin in (0, 8):
            ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=64,
                                        page_size=16, prefill_chunk=8,
                                        policy="fifo", num_classes=2,
                                        pin_pages=pin)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats = eng.run(self._trace(cfg), max_ticks=600)
            assert stats["completed"] == 12, f"pin={pin}"
            for req in eng.completed:
                oracle = _oracle_tokens(params, cfg, req, ecfg.max_cache, None)
                assert req.out_tokens == oracle, \
                    f"request {req.rid} diverged (pin={pin})"
            runs[pin] = stats
        assert runs[0]["pins"] == 0 and runs[0]["pages_pinned"] == 0
        assert runs[0]["pages_in_use"] == 0          # legacy free-on-zero
        assert runs[8]["pins"] >= 6                  # 2 tenants x 3 pages
        assert runs[8]["pinned_pages_adopted"] >= 6  # burst 2 hits the cache
        assert runs[8]["pinned_hit_rate"] > 0
        # drained: every resident page is a pinned cache entry, nothing leaked
        assert runs[8]["pages_in_use"] == runs[8]["pages_pinned"] > 0
        assert runs[8]["prefill_tokens"] < runs[0]["prefill_tokens"], \
            "pinning should cut prompt positions actually computed"

    def test_pin_budget_zero_without_sharing_is_legacy(self, dense):
        """No sharing -> no index -> nothing pinnable: the allocator forces
        pin_pages to 0 and the run behaves exactly like the old allocator."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=64, page_size=16,
                                    prefill_chunk=8, policy="fifo",
                                    num_classes=2, pin_pages=8,
                                    prefix_sharing=False)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(self._trace(cfg), max_ticks=600)
        assert stats["completed"] == 12
        assert stats["pin_pages"] == 0 and stats["pins"] == 0
        assert stats["pages_in_use"] == 0


class TestImmuneCostAccounting:
    """The immune cost memory must charge what a request actually held: a
    preempted-then-resumed request burns slot-ticks re-deriving its recorded
    tokens, and charging emissions alone would teach the memory that exactly
    the preempt-prone classes it should suppress were cheap."""

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_replayed_request_charges_more_than_unpreempted(self, dense):
        """Identical request pair through a tiny pool (forces preemption of
        the later arrival) vs an ample one (no preemption): the preempted
        class's EMA must come out strictly higher — replayed slot-ticks are
        charged — while the untouched class's EMA is identical."""
        cfg, params = dense
        runs = {}
        for name, num_pages in (("tiny", 3), ("ample", None)):
            ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32,
                                        page_size=16, num_pages=num_pages,
                                        policy="immune", num_classes=2,
                                        latency_budget=64.0)
            eng = eng_mod.Engine(params, cfg, ecfg)
            stats = eng.run(_make_requests(cfg, 2, prompt_lens=(10,),
                                           steps=(8,)), max_ticks=200)
            assert stats["completed"] == 2 and stats["shed"] == 0, name
            runs[name] = eng
        tiny, ample = runs["tiny"], runs["ample"]
        r0t, r1t = sorted(tiny.completed, key=lambda r: r.rid)
        r0a, r1a = sorted(ample.completed, key=lambda r: r.rid)
        # the tiny pool preempted the later arrival (class 1) and it replayed
        assert r1t.preemptions >= 1 and r1t.replayed_tokens >= 1
        assert r0t.replayed_tokens == 0 and r1a.replayed_tokens == 0
        # both runs emitted the same tokens; only the replay differs
        assert r1t.out_tokens == r1a.out_tokens
        # class 1's remembered cost reflects the replayed slot-ticks ...
        assert tiny.admission.remembered_cost(1) > \
            ample.admission.remembered_cost(1)
        # ... and the unpreempted class is charged identically in both runs
        assert tiny.admission.remembered_cost(0) == \
            pytest.approx(ample.admission.remembered_cost(0))


class TestBudgetUnits:
    """One unit per comparison: a declared ``deadline`` is wall-clock seconds
    judged against wall-clock latency; the engine-wide ``latency_budget`` is
    ticks judged against tick latency. The old ``_budget`` helper handed the
    wall-clock deadline to tick comparisons."""

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_deadline_generous_in_ticks_tight_in_wall_clock(self, dense):
        """A 50-second deadline on a ~9-tick request: the old code compared
        ticks (9 <= 50 -> met) no matter how slow the wall clock was. Judged
        in the deadline's own unit, a (simulated) 60 s wall latency misses and
        a 1 s one meets — tick latency must not leak into the comparison."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, policy="fifo")
        reqs = _make_requests(cfg, 1, prompt_lens=(6,), steps=(8,))
        reqs[0].deadline = 50.0
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=100)
        assert stats["completed"] == 1 and stats["deadline_requests"] == 1
        req = eng.completed[0]
        assert req.latency <= 50, "sanity: generous measured in ticks"
        # simulate the wall clock (real timing would flake under compile):
        # 60 s > the 50 s deadline -> missed, regardless of tick latency
        req.finish_time = req.submit_time + 60.0
        assert eng._met_budget(req) is False
        assert eng.stats()["goodput"] == 0.0
        # 1 s < 50 s -> met
        req.finish_time = req.submit_time + 1.0
        assert eng._met_budget(req) is True
        assert eng.stats()["goodput"] == 1.0

    def test_no_deadline_judged_in_ticks(self, dense):
        """Without a declared deadline the bar is the tick-denominated engine
        budget against tick latency — wall clock never enters."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, policy="fifo",
                                    latency_budget=5.0)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(_make_requests(cfg, 1, prompt_lens=(6,), steps=(8,)),
                        max_ticks=100)
        assert stats["completed"] == 1
        req = eng.completed[0]
        assert req.latency > 5, "sanity: blows the 5-tick budget"
        assert eng._met_budget(req) is False
        # wall clock (microseconds here) must not rescue a tick-budget miss
        lat, bar = eng._slo(req)
        assert (lat, bar) == (float(req.latency), 5.0)
