"""Unit + property tests for the immune load-balancing primitives."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import immune

jax.config.update("jax_platform_name", "cpu")


class TestImmuneMemory:
    def test_ema_converges_to_constant_signal(self):
        mem = immune.ImmuneMemory.create((4,), decay=0.9)
        for _ in range(200):
            mem = mem.update(jnp.full((4,), 3.0))
        np.testing.assert_allclose(mem.value, 3.0, atol=1e-3)

    @hypothesis.given(decay=st.floats(0.0, 0.99), x=st.floats(-10, 10))
    @hypothesis.settings(deadline=None, max_examples=20)
    def test_ema_bounded_by_signal_range(self, decay, x):
        mem = immune.ImmuneMemory.create((1,), decay=decay)
        for _ in range(50):
            mem = mem.update(jnp.asarray([x]))
        assert float(jnp.abs(mem.value[0])) <= abs(x) + 1e-6


class TestTwoStageRegulator:
    def test_fast_rise_then_delayed_suppression(self):
        """The paper's signature: response spikes quickly, the suppressor builds
        *later* and pulls the response down — without cancelling the initial rise."""
        reg = immune.TwoStageRegulator.create()
        state = reg.init(())
        trace = []
        for _ in range(300):
            state = reg.step(state, jnp.asarray(1.0))
            trace.append(float(state.response))
        trace = np.asarray(trace)
        peak = trace.argmax()
        assert trace[peak] > trace[-1] * 1.2, "no overshoot-then-suppress dynamics"
        assert peak < 150, "rise was not fast"
        assert trace[-1] > 0.1, "suppression killed the response entirely"

    def test_bounded_no_runaway(self):
        reg = immune.TwoStageRegulator.create(self_excite=0.3)
        state = reg.init((8,))
        for _ in range(2000):
            state = reg.step(state, jnp.ones((8,)))
        assert bool(jnp.all(jnp.isfinite(state.response)))
        assert float(jnp.max(state.response)) < 1e3

    @hypothesis.given(stim=st.floats(0.0, 5.0))
    @hypothesis.settings(deadline=None, max_examples=15)
    def test_nonnegative_states(self, stim):
        reg = immune.TwoStageRegulator.create()
        state = reg.init(())
        for _ in range(100):
            state = reg.step(state, jnp.asarray(stim))
        assert float(state.response) >= 0 and float(state.suppressor) >= 0


class TestAnergy:
    def test_uncostimulated_becomes_anergic_and_revives(self):
        gate = immune.AnergyGate.create(onset=0.5, revival=0.5)
        state = gate.init(())
        for _ in range(20):
            state = gate.step(state, stimulus=jnp.asarray(1.0),
                              costimulus=jnp.asarray(0.0))
        assert float(state.level) > 0.9
        assert float(gate.gate(state, jnp.asarray(1.0))) < 0.1
        for _ in range(20):
            state = gate.step(state, jnp.asarray(0.0), jnp.asarray(0.0), il2=1.0)
        assert float(state.level) < 0.1

    def test_costimulated_stays_active(self):
        gate = immune.AnergyGate.create()
        state = gate.init(())
        for _ in range(50):
            state = gate.step(state, jnp.asarray(1.0), jnp.asarray(1.0))
        assert float(state.level) < 1e-6


class TestDominance:
    def test_scatter_max_resolves_conflicts(self):
        grid = jnp.zeros((4, 4), jnp.int32)
        rows = jnp.asarray([1, 1, 2])
        cols = jnp.asarray([1, 1, 3])
        vals = jnp.asarray([5, 9, 2])
        out = immune.dominance_scatter_max(grid, rows, cols, vals)
        assert int(out[1, 1]) == 9 and int(out[2, 3]) == 2

    @hypothesis.given(st.lists(st.booleans(), min_size=1, max_size=16))
    @hypothesis.settings(deadline=None, max_examples=25)
    def test_at_most_one_winner(self, claims):
        ids = jnp.arange(len(claims))
        winners = immune.dominance_resolve(ids, jnp.asarray(claims))
        n = int(jnp.sum(winners))
        assert n == (1 if any(claims) else 0)
        if any(claims):
            # dominance picks the highest claiming id
            assert bool(winners[max(i for i, c in enumerate(claims) if c)])


class TestLimitCycleDamping:
    def test_ancestor_transitions_damped_others_untouched(self):
        p = immune.damp_ancestor_transition(jnp.asarray(1.0), jnp.asarray(2),
                                            jnp.asarray(2), damping=0.1)
        assert float(p) == pytest.approx(0.1)
        p = immune.damp_ancestor_transition(jnp.asarray(1.0), jnp.asarray(2),
                                            jnp.asarray(3), damping=0.1)
        assert float(p) == pytest.approx(1.0)

    def test_hysteresis_asymmetric(self):
        up = immune.hysteresis(jnp.asarray(0.0), jnp.asarray(1.0), 0.5, 0.1)
        down = immune.hysteresis(jnp.asarray(1.0), jnp.asarray(0.0), 0.5, 0.1)
        assert float(up) == pytest.approx(0.5)
        assert float(down) == pytest.approx(0.9)
