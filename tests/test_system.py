"""End-to-end behaviour: train-to-learn, serve, elastic reshard, dry-run subprocess."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import TrainConfig
from repro.dist import checkpoint as ckpt
from repro.serve import decode as serve
from repro.train import train_step as ts
from repro.train.trainer import Trainer
from repro.models import model

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_end_to_end_training_learns_bigram_structure(tmp_path):
    """The synthetic corpus is a 4-way bigram chain: optimal loss ~= ln(4), uniform
    init ~= ln(vocab). A tiny model must close most of that gap."""
    cfg = configs.get_config("smollm-360m").smoke()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=4,
                              num_kv_heads=2, head_dim=16, d_ff=128,
                              vocab_size=64)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=10, decay_steps=5000)
    tr = Trainer(cfg=cfg, tcfg=tcfg, workdir=str(tmp_path), batch=8, seq=64,
                 ckpt_every=1000, log_every=20)
    tr.train(150)
    final = tr.history[-1]["loss"]
    assert final < 0.5 * np.log(64) + 0.5 * np.log(4), final


def test_moe_end_to_end_with_immune_balancing(tmp_path):
    cfg = configs.get_config("granite-moe-3b-a800m").smoke()
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=5000)
    tr = Trainer(cfg=cfg, tcfg=tcfg, workdir=str(tmp_path), batch=4, seq=32,
                 ckpt_every=1000, log_every=10)
    tr.train(60)
    assert tr.history[-1]["loss"] < tr.history[0]["loss"] - 0.2
    # balancing keeps the observed load CV bounded
    assert tr.history[-1]["load_cv"] < 2.0


def test_serving_generates_deterministically():
    cfg = configs.get_config("smollm-360m").smoke()
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (3, 12), 0,
                                            cfg.vocab_size)}
    toks1, _ = serve.generate(params, cfg, prompts, max_cache=64, steps=8)
    toks2, _ = serve.generate(params, cfg, prompts, max_cache=64, steps=8)
    assert toks1.shape == (3, 8)
    np.testing.assert_array_equal(toks1, toks2)
    assert bool(jnp.all((toks1 >= 0) & (toks1 < cfg.vocab_size)))


def test_elastic_reshard_roundtrip(tmp_path):
    """A checkpoint saved under one (implicit) sharding restores under another
    device placement — leaves are stored gathered."""
    cfg = configs.get_config("smollm-360m").smoke()
    tcfg = TrainConfig()
    state = ts.init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    ckpt.save(str(tmp_path), state, step=1)
    restored, step = ckpt.restore(str(tmp_path), state)
    assert step == 1
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_anergy_checkpoint_restore_revival_loop(tmp_path):
    """The full paper loop at fleet level: a worker stops heartbeating and is
    anergized (clonal deletion); the run then crashes and auto-resumes from the
    checkpoint — *including* the scheduler's membership memory, so the dead
    worker stays excluded; when its heartbeat returns it is revived and gets
    its shard fraction back (elastic membership)."""
    from repro.core import scheduler as ischeduler

    cfg = configs.get_config("smollm-360m").smoke()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=32, num_heads=2,
                              num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=128)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=1000)
    scfg = ischeduler.SchedulerConfig(mem_decay=0.5, anergy_floor=0.1,
                                      revival_steps=3)
    dead_worker = 2

    def heartbeats(step, tput):
        hb = np.ones((4,), np.float32)
        if step >= 3:                     # node loss: worker 2 stops reporting
            hb[dead_worker] = 0.0
        return hb

    mk = lambda **kw: Trainer(cfg=cfg, tcfg=tcfg, workdir=str(tmp_path), batch=4,
                              seq=32, ckpt_every=10, log_every=5, num_workers=4,
                              scfg=scfg, **kw)
    tr = mk(heartbeats=heartbeats, failure_at=13)
    with pytest.raises(RuntimeError, match="injected node failure"):
        tr.train(30)
    assert bool(tr.scheduler.anergic[dead_worker]), "worker never anergized"
    assert float(tr.scheduler.frac[dead_worker]) == 0.0

    # resume: the restored scheduler remembers who is presumed dead ...
    tr2 = mk(heartbeats=lambda step, tput: np.ones((4,), np.float32))
    _, step = tr2.init_or_restore()
    assert step == 10
    assert bool(tr2.scheduler.anergic[dead_worker]), \
        "anergy verdict lost across checkpoint restore"
    # ... and the returning heartbeat revives the worker (elastic rejoin)
    tr2.train(30)
    assert not bool(tr2.scheduler.anergic[dead_worker]), "worker never revived"
    assert float(tr2.scheduler.frac[dead_worker]) > 0.05
    assert tr2.history[-1]["anergic_workers"] == 0


@pytest.mark.slow
def test_multi_device_dryrun_subprocess(tmp_path):
    """Integration check of deliverable (e): lower+compile one cell on the real
    512-device production mesh in a fresh subprocess (XLA flags are per-process)."""
    out = tmp_path / "dry.jsonl"
    for extra in ([], ["--multi-pod"]):
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-360m",
             "--shape", "decode_32k", "--out", str(out)] + extra,
            cwd=REPO, env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
            capture_output=True, text=True, timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
    recs = [json.loads(l) for l in open(out)]
    assert {rec["mesh"] for rec in recs} == {"16x16", "2x16x16"}
    assert all(rec["status"] == "ok" for rec in recs)
    assert all(rec["chips"] in (256, 512) for rec in recs)
