"""Slot groups (serve.groups): best-of-n lanes sharing prompt pages.

A parent request with ``SamplingParams.n`` / ``best_of`` > 1 expands into
member lanes that admit jointly (lane 0 prefills and registers the shared
prefix, siblings defer and adopt its pages — the prompt is charged once),
are preempted and cancelled as a unit, and retire into one assembled parent
output (``best_of`` ranks lanes by cumulative chosen-token logprob). The
joint-finish contract holds through every fleet layer grown so far: a group
pins to one replica, survives that replica's crash by re-placing together
(PR 8 failover), fails whole when a member exhausts its retry budget, and
replays through journal recovery to the identical assembly (PR 9).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import api, durability
from repro.serve import engine as eng_mod
from repro.serve import groups
from repro.serve import router as rt_mod
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.faults import FaultInjector, FaultPlan

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    return cfg, model.init_params(jax.random.PRNGKey(0), cfg)


def _ecfg(**kw):
    base = dict(num_slots=3, max_cache=64, page_size=16, prefill_chunk=8,
                policy="fifo")
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _parent(cfg, rid=0, plen=32, steps=6, seed=0, arrival=0, **pkw):
    rng = np.random.default_rng(1000 + rid)
    return ServeRequest(
        rid=rid,
        tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
        params=SamplingParams(seed=seed + 10 * rid, max_new_tokens=steps,
                              **pkw),
        rclass=rid % 2, arrival=arrival)


def _fresh(req):
    """A fresh parent record with the same prompt/params, for oracle replay
    (``api.generate`` mutates and assembles the record it is given)."""
    return ServeRequest(rid=req.rid, tokens=req.tokens, params=req.params,
                        rclass=req.rclass)


def _stream_outputs(eng, reqs, max_ticks=400):
    finals = {}
    for out in eng.stream(reqs, max_ticks=max_ticks):
        if out.finished:
            finals[out.rid] = out
    return finals


# ---------------------------------------------------------------------------
# group math (model-free)
# ---------------------------------------------------------------------------
class TestGroupMath:
    def test_member_rid_round_trip(self):
        rid = groups.member_rid(37, 5)
        assert groups.is_member_rid(rid)
        assert groups.parent_rid_of(rid) == 37
        assert groups.lane_of(rid) == 5
        assert not groups.is_member_rid(37)
        with pytest.raises(ValueError):
            groups.member_rid(0, groups.LANE_STRIDE)

    def test_expand_member_params(self):
        parent = ServeRequest(
            rid=3, tokens=np.arange(8, dtype=np.int32),
            params=SamplingParams(n=1, best_of=3, temperature=0.7, seed=50,
                                  max_new_tokens=4))
        members = groups.expand(parent)
        assert [m.lane for m in members] == [0, 1, 2]
        assert [m.params.seed for m in members] == [50, 51, 52]
        assert all(m.params.n == 1 and m.params.best_of == 0 for m in members)
        # best_of forces chosen-logprob recording so lanes are comparable
        assert all(m.params.logprobs >= 1 for m in members)
        # identical prompt array: byte-identical pages for the prefix index
        assert all(m.tokens is parent.tokens for m in members)
        assert all(m.group == 3 and m.group_size == 3 for m in members)
        # idempotent on members and on standalone requests
        assert groups.expand(members[1]) == [members[1]]
        lone = ServeRequest(rid=9, tokens=np.arange(4, dtype=np.int32))
        assert groups.expand(lone) == [lone]

    def test_plain_n_keeps_lane_order_no_logprobs(self):
        parent = ServeRequest(rid=0, tokens=np.arange(4, dtype=np.int32),
                              params=SamplingParams(n=2, temperature=1.0))
        members = groups.expand(parent)
        assert all(m.params.logprobs == 0 for m in members)

    def test_rank_by_cum_logprob_then_lane(self):
        def m(lane, lps):
            r = ServeRequest(rid=groups.member_rid(0, lane),
                             tokens=np.arange(2, dtype=np.int32),
                             group=0, lane=lane)
            r.out_logprobs = lps
            return r
        members = [m(0, [-2.0, -2.0]), m(1, [-0.5, -0.5]), m(2, [-1.0, -2.0])]
        assert groups.rank(members) == [1, 2, 0]
        # no logprobs anywhere -> lane order
        bare = [m(2, []), m(0, []), m(1, [])]
        assert [bare[i].lane for i in groups.rank(bare)] == [0, 1, 2]

    def test_assemble_abnormal_reason_wins(self):
        parent = ServeRequest(rid=0, tokens=np.arange(4, dtype=np.int32),
                              params=SamplingParams(n=2, temperature=1.0))
        members = groups.expand(parent)
        outs = []
        for i, m in enumerate(members):
            m.out_tokens = [i, i + 1]
            m.finish_reason = "length" if i == 0 else "shed"
            m.finish_tick = 5 + i
            outs.append(api.RequestOutput(
                rid=m.rid, new_tokens=m.out_tokens, tokens=m.out_tokens,
                finished=True, finish_reason=m.finish_reason, tick=5 + i))
        done = groups.assemble(parent, members, outs)
        assert done.finish_reason == "shed"
        assert done.finished and done.rid == 0
        assert len(done.group_outputs) == 2


# ---------------------------------------------------------------------------
# engine: joint admission / shared prompt pages / assembly
# ---------------------------------------------------------------------------
class TestEngineGroups:
    def test_n2_group_assembles_and_matches_oneshot(self, dense):
        """One parent, two sampled lanes: exactly one assembled parent output
        whose lanes match the one-shot facade bitwise, with the shared
        prompt prefilled once and adopted by the sibling."""
        cfg, params = dense
        parent = _parent(cfg, plen=32, steps=6, n=2, temperature=0.8,
                         top_p=0.9)
        oracle = api.generate(params, cfg, _fresh(parent), max_cache=64)
        eng = eng_mod.Engine(params, cfg, _ecfg())
        finals = _stream_outputs(eng, [parent])
        assert set(finals) == {parent.rid,
                               groups.member_rid(parent.rid, 0),
                               groups.member_rid(parent.rid, 1)}
        done = finals[parent.rid]
        assert done.finish_reason == "length"
        assert len(done.group_outputs) == 2
        assert done.tokens == oracle.tokens
        assert [o.tokens for o in done.group_outputs] \
            == [o.tokens for o in oracle.group_outputs]
        stats = eng.stats()
        assert stats["groups_submitted"] == 1
        assert stats["group_members_completed"] == 2
        # the 32-token prompt is charged once: lane 0 prefills 2 pages, the
        # sibling adopts them and only recomputes the final prompt position
        # (its seed logits) — 32 + 1 prefilled positions, not 64
        members = [r for r in eng.completed if r.group >= 0]
        assert sum(m.prefill_tokens for m in members) == 33
        assert stats["shared_pages_adopted"] >= 2

    def test_best_of_ranks_by_cum_logprob(self, dense):
        cfg, params = dense
        parent = _parent(cfg, plen=16, steps=5, n=1, best_of=3,
                         temperature=1.0, top_p=0.9)
        oracle = api.generate(params, cfg, _fresh(parent), max_cache=64)
        eng = eng_mod.Engine(params, cfg, _ecfg())
        finals = _stream_outputs(eng, [parent])
        done = finals[parent.rid]
        assert len(done.group_outputs) == 1       # best_of keeps n lanes
        assert done.tokens == oracle.tokens
        members = sorted((r for r in eng.completed if r.group >= 0),
                         key=lambda r: r.lane)
        cums = [sum(m.out_logprobs) for m in members]
        assert done.tokens == members[int(np.argmax(cums))].out_tokens, \
            "best_of winner is not the max-cum-logprob lane"

    def test_greedy_group_lanes_are_identical(self, dense):
        """Greedy lanes differ only in seed, which greedy never draws — the
        degenerate-but-well-defined case."""
        cfg, params = dense
        parent = _parent(cfg, plen=16, steps=5, n=2)
        eng = eng_mod.Engine(params, cfg, _ecfg())
        finals = _stream_outputs(eng, [parent])
        outs = finals[parent.rid].group_outputs
        assert outs[0].tokens == outs[1].tokens

    def test_oversized_group_rejected_whole(self, dense):
        """One probe decides the whole group: a prompt+budget that cannot fit
        rejects the parent before any member is queued — never
        half-scheduled."""
        cfg, params = dense
        parent = _parent(cfg, plen=60, steps=20, n=2, temperature=1.0)
        eng = eng_mod.Engine(params, cfg, _ecfg(max_cache=64))
        finals = _stream_outputs(eng, [parent], max_ticks=30)
        assert set(finals) == {parent.rid}
        assert finals[parent.rid].finish_reason == "rejected"
        assert eng.stats()["groups_submitted"] == 0
        assert not eng.queue


class TestGroupPreemption:
    def test_member_preempted_mid_draft_cascades_and_replays(self, dense):
        """Page pressure evicts one lane of a spec-decoding group: the
        cascade preempts its resident sibling too (descending lane, lane 0
        back at the queue front), and the re-admitted group still assembles
        bitwise the one-shot facade's lanes."""
        cfg, params = dense
        ecfg = _ecfg(num_slots=3, max_cache=96, page_size=8, num_pages=11,
                     admission_mode="preempt", spec_decode=3,
                     spec_draft_layers=1)
        hog = ServeRequest(rid=0, tokens=np.arange(16, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=40),
                           arrival=0)
        parent = _parent(cfg, rid=1, plen=32, steps=8, n=2, arrival=2)
        oracle = api.generate(params, cfg, _fresh(parent), max_cache=96)
        eng = eng_mod.Engine(params, cfg, ecfg)
        finals = _stream_outputs(eng, [hog, parent], max_ticks=600)
        stats = eng.stats()
        assert stats["spec_ticks"] > 0
        assert stats["preemptions"] > 0, "page pressure never preempted"
        lanes = {groups.member_rid(parent.rid, ln) for ln in (0, 1)}
        assert lanes <= eng.preempted_rids, \
            "preempting one member did not cascade to its resident sibling"
        done = finals[parent.rid]
        assert done.finish_reason == "length"
        assert done.tokens == oracle.tokens
        assert [o.tokens for o in done.group_outputs] \
            == [o.tokens for o in oracle.group_outputs]
        assert finals[hog.rid].finish_reason == "length"


# ---------------------------------------------------------------------------
# router: co-placement, crash failover, retry exhaustion, journal recovery
# ---------------------------------------------------------------------------
def _rcfg(**kw):
    base = dict(num_slots=2, max_cache=96, page_size=16, prefill_chunk=8,
                policy="immune", num_classes=3, latency_budget=96.0)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _group_trace(cfg, parents=3, plen=32, steps=6, n=2, **pkw):
    return [_parent(cfg, rid=rid, plen=plen, steps=steps, n=n,
                    arrival=rid * 2, **pkw) for rid in range(parents)]


class TestRouterGroups:
    def test_groups_pin_to_one_replica_and_assemble(self, dense):
        cfg, params = dense
        trace = _group_trace(cfg, parents=3, temperature=0.8, top_p=0.9)
        oracles = {r.rid: api.generate(params, cfg, _fresh(r), max_cache=96)
                   for r in trace}
        router = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(2)],
            rt_mod.RouterConfig(policy="immune"))
        stats = router.run(trace)
        g = stats["groups"]
        assert g["submitted"] == 3 and g["assembled"] == 3
        assert g["pending"] == 0 and g["failed_groups"] == 0
        # every non-lane-0 member was routed by its group's pin
        assert g["coplacements"] >= 3
        for done in router.group_outputs:
            oracle = oracles[done.rid]
            assert done.tokens == oracle.tokens
            assert [o.tokens for o in done.group_outputs] \
                == [o.tokens for o in oracle.group_outputs]

    def test_group_straddles_replica_crash(self, dense):
        """Crash the whole fleet's worth of pinned groups one replica at a
        time is overkill — one crash suffices: a group living on the dead
        replica clears its pin, re-places *together* on survivors, and
        assembles bitwise the fault-free run's output."""
        cfg, params = dense
        ref_router = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(3)],
            rt_mod.RouterConfig(policy="rr"))
        ref_router.run(_group_trace(cfg, parents=3))
        ref = {o.rid: o for o in ref_router.group_outputs}
        assert len(ref) == 3

        router = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(3)],
            rt_mod.RouterConfig(policy="rr"),
            injector=FaultInjector(FaultPlan.parse("crash@4:r0")))
        stats = router.run(_group_trace(cfg, parents=3))
        assert stats["deaths"] == 1
        g = stats["groups"]
        assert g["assembled"] == 3 and g["pending"] == 0
        assert g["failed_groups"] == 0
        assert stats["unserved"] == 0
        for done in router.group_outputs:
            assert done.finish_reason == ref[done.rid].finish_reason
            assert done.tokens == ref[done.rid].tokens, \
                f"group {done.rid} diverged across the crash"
            assert [o.tokens for o in done.group_outputs] \
                == [o.tokens for o in ref[done.rid].group_outputs]

    def test_retry_exhausted_group_fails_whole(self, dense):
        """With a zero retry budget, a member evacuated off the dead replica
        terminates "failed" — and the joint-finish contract fails its whole
        group, never leaving sibling lanes half-alive."""
        cfg, params = dense
        router = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(2)],
            rt_mod.RouterConfig(policy="rr", max_retries=0),
            injector=FaultInjector(FaultPlan.parse("crash@4:r0")))
        stats = router.run(_group_trace(cfg, parents=2, steps=8))
        assert stats["deaths"] == 1
        g = stats["groups"]
        assert g["failed_groups"] >= 1
        assert g["assembled"] == 2 and g["pending"] == 0
        failed = [o for o in router.group_outputs
                  if o.finish_reason == "failed"]
        assert failed, "no assembled group carries the failed reason"
        assert stats["unserved"] == 0

    def test_group_replays_through_journal_recovery(self, dense, tmp_path):
        """A full-fleet power loss with groups in flight: recovery rebuilds
        parents from journaled member records and every group assembles
        exactly once, bitwise the uninterrupted fleet's output."""
        cfg, params = dense
        ref_router = rt_mod.Router(
            [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(2)],
            rt_mod.RouterConfig(policy="immune"))
        ref_stats = ref_router.run(_group_trace(cfg, parents=3))
        ref = {o.rid: o for o in ref_router.group_outputs}
        assert len(ref) == 3
        off = max(2, ref_stats["ticks"] // 2)

        def factory():
            inj = FaultInjector(
                FaultPlan.parse(f"poweroff@{off} restart@{off + 3}"))
            fleet = [eng_mod.Engine(params, cfg, _rcfg()) for _ in range(2)]
            return rt_mod.Router(fleet, rt_mod.RouterConfig(policy="immune"),
                                 injector=inj)

        rt, stats = durability.run_durable(factory, _group_trace(cfg, parents=3),
                                           str(tmp_path / "wal"))
        assert stats["restarts"] == 1
        g = stats["groups"]
        assert g["pending"] == 0
        got = {o.rid: o for o in rt.group_outputs}
        assert set(got) == set(ref), "a group assembled zero or twice"
        for rid, done in got.items():
            assert done.finish_reason == "length"
            assert done.tokens == ref[rid].tokens, \
                f"group {rid} diverged across the power loss"
            assert [o.tokens for o in done.group_outputs] \
                == [o.tokens for o in ref[rid].group_outputs]
