"""dist/sharding layout policy: spec rules (fast, in-process) + real placement
on 8 host devices (subprocess — XLA device count locks at first init)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.configs.base import ParallelConfig, TrainConfig
from repro.dist import sharding as shd
from repro.models import model
from repro.train import train_step as ts

jax.config.update("jax_platform_name", "cpu")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mesh11():
    """1x1 ('data','model') mesh: every axis size divides every dim, so the
    guard keeps all rule axes — the full layout policy is assertable on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


class TestGuard:
    def test_drops_unknown_and_nondividing_axes(self):
        axes = {"data": 4, "model": 8}
        assert shd._guard(("model", "data"), (16, 8), axes) == P("model", "data")
        assert shd._guard(("model", None), (12, 8), axes) == P(None, None)
        assert shd._guard(("ghost", "data"), (16, 8), axes) == P(None, "data")
        # tuple entries filter to the axes the mesh has
        assert shd._guard((("pod", "data"), None), (8, 3), axes) == \
            P(("data",), None)
        # a tuple whose product doesn't divide the dim is dropped whole
        assert shd._guard((("pod", "data"), None), (6, 3), axes) == P(None, None)

    def test_guard_is_the_constrain_policy(self):
        """dist/sharding and models/layers apply literally the same guard
        (layers.guard_entry) — this pins the shared helper so the two layout
        policies cannot drift apart again."""
        from repro.models import layers
        assert shd._guard is not layers.guard_entry      # wrapper, same policy
        axes = {"data": 4, "model": 8}
        for spec, dim in [("model", 16), ("model", 12), ("ghost", 16),
                          (("pod", "data"), 8), (("pod", "data"), 6),
                          (None, 7)]:
            assert shd._guard((spec,), (dim,), axes) == \
                P(layers.guard_entry(spec, dim, axes))
        # unknown axis sizes (recorded as 0 by set_mesh_axes without sizes)
        # skip the divisibility check instead of dropping everything
        assert layers.guard_entry("model", 12, {"model": 0}) == "model"
        # list specs filter like tuple specs (constrain's extra input shape)
        assert layers.guard_entry(["pod", "data"], 8, axes) == ("data",)


class TestParamLayout:
    def test_dense_policy(self):
        cfg = configs.get_config("smollm-360m").smoke()
        mesh = _mesh11()
        params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0),
                                                          cfg))
        sh = shd.param_shardings(params, cfg, mesh, ParallelConfig())
        assert sh["embed"].spec == P("model", "data")       # vocab TP + fsdp
        blk = sh["stack"][0][0]                             # (depth, ...) stacked
        assert blk["mixer"]["wq"].spec == P(None, "data", "model")
        assert blk["mixer"]["wo"].spec == P(None, "model", "data")
        assert blk["mlp"]["w_gate"].spec == P(None, "data", "model")
        assert blk["mlp"]["w_down"].spec == P(None, "model", "data")
        assert blk["norm1"]["scale"].spec == P()            # replicated
        # fsdp off drops the 'data' factor but keeps TP
        sh2 = shd.param_shardings(params, cfg, mesh, ParallelConfig(fsdp=False))
        assert sh2["stack"][0][0]["mixer"]["wq"].spec == P(None, None, "model")

    def test_moe_expert_parallel_policy(self):
        cfg = configs.get_config("granite-moe-3b-a800m").smoke()
        mesh = _mesh11()
        params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0),
                                                          cfg))
        sh = shd.param_shardings(params, cfg, mesh, ParallelConfig())
        moe = sh["stack"][0][0]["moe"]
        assert moe["w_gate"].spec == P(None, "model", "data", None)  # E over TP
        assert moe["w_down"].spec == P(None, "model", "data", None)
        sh2 = shd.param_shardings(params, cfg, mesh,
                                  ParallelConfig(expert_parallel=False))
        assert sh2["stack"][0][0]["moe"]["w_gate"].spec == \
            P(None, None, "data", "model")                  # fall back to TP on F

    def test_train_state_factored_moments_follow_params(self):
        cfg = configs.get_config("smollm-360m").smoke()
        mesh = _mesh11()
        state = jax.eval_shape(lambda: ts.init_train_state(
            jax.random.PRNGKey(0), cfg, TrainConfig(), factored=True))
        sh = shd.train_state_shardings(state, cfg, mesh, ParallelConfig())
        blk = sh.opt.mu["stack"][0][0]
        assert blk["mlp"]["w_down"].spec == P(None, "model", "data")
        nu = sh.opt.nu["stack"][0][0]["mlp"]["w_down"]      # {'row','col'} dict
        assert nu["row"].spec == P(None, "model")           # drops last dim
        assert nu["col"].spec == P(None, "data")            # drops middle dim
        assert sh.step.spec == P()

    def test_batch_and_cache_policy(self):
        cfg = configs.get_config("smollm-360m").smoke()
        mesh = _mesh11()
        sds = jax.ShapeDtypeStruct
        batch = {"tokens": sds((8, 64), jax.numpy.int32)}
        bs = shd.batch_shardings(batch, mesh, ParallelConfig())
        assert bs["tokens"].spec == P(("data",), None)
        bs2 = shd.batch_shardings(batch, mesh, ParallelConfig(seq_shard=True))
        assert bs2["tokens"].spec == P(("data",), "model")
        cache = jax.eval_shape(lambda: model.init_cache(cfg, 4, 32))
        cs = shd.cache_shardings(cache, cfg, mesh, ParallelConfig())
        kv = cs["layers"][0][0]["k"]                        # (depth,B,S,Hkv,D)
        assert kv.spec == P(None, ("data",), None, "model", None)
        assert cs["pos"].spec == P()


def test_train_state_places_on_8_device_mesh(tmp_path):
    """End-to-end placement: a smoke train state laid out by
    train_state_shardings on a real 2x4 host-device mesh, values intact."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                                   + os.environ.get("XLA_FLAGS", ""))
        import jax, numpy as np
        from repro import configs
        from repro.configs.base import ParallelConfig, TrainConfig
        from repro.dist import sharding as shd
        from repro.train import train_step as ts
        cfg = configs.get_config("smollm-360m").smoke()
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        state = ts.init_train_state(jax.random.PRNGKey(0), cfg, TrainConfig())
        sh = shd.train_state_shardings(state, cfg, mesh, ParallelConfig())
        placed = jax.device_put(state, sh)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(placed)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        n_sharded = sum(len(x.sharding.device_set) > 1
                        for x in jax.tree.leaves(placed))
        assert n_sharded > 0, "nothing actually sharded on the 8-device mesh"
        print("OK", n_sharded)
    """)
    r = subprocess.run([sys.executable, "-c", script], cwd=REPO,
                       env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert r.stdout.startswith("OK")
