"""Unified serving API: SamplingParams / RequestOutput + the parity oracle.

PR 2-4 pinned the engine with a token-exact *greedy* oracle. With per-request
sampling the oracle moves down a level:

  * **bitwise logits parity** — the engine's per-token logits rows
    (``EngineConfig.capture_logits``) must equal one-shot
    ``decode.generate(return_logits=True)``'s exactly, below the sampler;
  * **seeded token parity** — a temperature>0 request with a fixed seed must
    emit identical tokens on the engine and the one-shot ``api.generate``
    facade, because both run the same ``model.sample_tokens`` lane with the
    same fold_in(key, emitted-count) discipline.

Greedy stays the hard anchor: temperature=0 requests must be bitwise the old
argmax path even when they share the (sticky-sampling) compiled decode step
with sampled neighbours — dense, MoE, and over shared/CoW-forked pages.
Retirement is per-request now: stop-token ids and ``max_new_tokens`` free the
slot's pages the tick they trigger, observable through ``Engine.stream()``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model
from repro.serve import api, decode, traces
from repro.serve import engine as eng_mod
from repro.serve.api import SamplingParams, ServeRequest

jax.config.update("jax_platform_name", "cpu")


def _smoke_cfg(arch):
    return configs.get_config(arch).smoke()


def _params(cfg):
    return model.init_params(jax.random.PRNGKey(0), cfg)


def _bias(cfg):
    return (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)


def _mixed_requests(cfg, n, seed=0, prompt_lens=(6, 10), steps=(5, 8),
                    stagger=1, sampled_every=2, temperature=0.9):
    """Interleaved greedy and seeded-sampled requests — every engine run here
    exercises the sticky-sampling compiled step with both lane kinds."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = prompt_lens[rid % len(prompt_lens)]
        temp = temperature if rid % sampled_every else 0.0
        req = ServeRequest(
            rid=rid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            params=SamplingParams(temperature=temp, top_p=0.9, top_k=40,
                                  seed=1000 + rid,
                                  max_new_tokens=steps[rid % len(steps)]),
            rclass=rid % 2,
            arrival=rid * stagger)
        reqs.append(traces.attach_modality_inputs(req, cfg, rng))
    return reqs


def _shared_family(cfg, sampled_rids=(), seed=0):
    """A crafted shared-prefix request family (mirrors test_serve_engine's):
    a 48-token donor, a follower whose prompt is a strict prefix of it
    (full-page hits + a partial-page hit that must CoW-fork), a same-prompt
    twin, and two requests behind a second prefix. ``sampled_rids`` get a
    seeded temperature>0 lane; the rest stay greedy."""
    rng = np.random.default_rng(seed)
    donor = rng.integers(0, cfg.vocab_size, size=48).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, size=32).astype(np.int32)

    def mk(rid, tokens, steps, arrival):
        temp = 0.8 if rid in sampled_rids else 0.0
        return ServeRequest(
            rid=rid, tokens=tokens, arrival=arrival,
            params=SamplingParams(temperature=temp, top_p=0.9,
                                  seed=50 + rid, max_new_tokens=steps))

    return [
        mk(0, donor.copy(), 12, 0),
        mk(1, donor[:40].copy(), 6, 8),      # full-page hits + partial -> CoW
        mk(2, donor.copy(), 5, 10),          # identical prompt -> CoW
        mk(3, np.concatenate([other, rng.integers(
            0, cfg.vocab_size, size=6).astype(np.int32)]), 6, 12),
        mk(4, np.concatenate([other, rng.integers(
            0, cfg.vocab_size, size=9).astype(np.int32)]), 5, 20),
    ]


def _replay(params, cfg, req, max_cache, bias=None, capture=False):
    """One-shot facade replay of an engine-served request (fresh record, same
    prompt/params) — the oracle side of every parity assertion."""
    probe = ServeRequest(rid=req.rid, tokens=req.tokens, params=req.params,
                         patches=req.patches, frames=req.frames)
    out = api.generate(params, cfg, probe, max_cache=max_cache,
                       router_bias=bias, capture_logits=capture)
    return probe, out


class TestSamplingParams:
    def test_validation(self):
        with pytest.raises(ValueError):
            SamplingParams(temperature=-0.1)
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError):
            SamplingParams(top_p=1.5)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1)
        with pytest.raises(ValueError):
            SamplingParams(max_new_tokens=0)

    def test_greedy_flag_and_stop_normalization(self):
        assert SamplingParams().is_greedy
        assert not SamplingParams(temperature=0.5).is_greedy
        assert SamplingParams(stop=[3, np.int64(7)]).stop == (3, 7)

    def test_key_is_deterministic(self):
        assert np.array_equal(SamplingParams(seed=5).key(),
                              SamplingParams(seed=5).key())
        assert not np.array_equal(SamplingParams(seed=5).key(),
                                  SamplingParams(seed=6).key())


class TestGreedyBitwise:
    """temperature=0 must stay the exact old argmax path even when the engine
    runs its sticky-sampling compiled step alongside sampled lanes."""

    def test_dense_mixed_lanes_greedy_requests_match_old_oracle(self):
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 6)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 6
        assert stats["sampled_requests"] == 3     # the step really sampled
        for req in eng.completed:
            if not req.params.is_greedy:
                continue
            # the PR 2-4 oracle, untouched: raw greedy decode.generate
            toks, _ = decode.generate(params, cfg, req.prompts(),
                                      max_cache=ecfg.max_cache,
                                      steps=req.max_new_tokens)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"greedy request {req.rid} diverged beside sampled lanes"

    def test_moe_mixed_lanes_greedy_requests_match_old_oracle(self):
        cfg = _smoke_cfg("granite-moe-3b-a800m")
        params = _params(cfg)
        bias = _bias(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 4, seed=1, steps=(4, 6))
        eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4 and stats["sampled_requests"] == 2
        for req in eng.completed:
            if not req.params.is_greedy:
                continue
            toks, _ = decode.generate(params, cfg, req.prompts(),
                                      max_cache=ecfg.max_cache,
                                      steps=req.max_new_tokens,
                                      router_bias=bias)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"moe greedy request {req.rid} diverged beside sampled lanes"

    def test_greedy_over_shared_and_cow_pages(self):
        """Sharing + sampling at once: greedy requests decoding over adopted
        and CoW-forked pages, beside sampled lanes, still bitwise-match."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=64, policy="fifo",
                                    prefill_chunk=8)
        reqs = _shared_family(cfg, sampled_rids=(1, 4))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 5
        assert stats["shared_pages_adopted"] >= 4
        assert stats["cow_forks"] + stats["nowrite_adoptions"] >= 2
        assert stats["sampled_requests"] == 2
        for req in eng.completed:
            if not req.params.is_greedy:
                continue
            toks, _ = decode.generate(params, cfg, req.prompts(),
                                      max_cache=ecfg.max_cache,
                                      steps=req.max_new_tokens)
            assert req.out_tokens == [int(t) for t in np.asarray(toks[0])], \
                f"greedy request {req.rid} diverged over shared pages"


class TestSeededSampling:
    def test_engine_tokens_match_oneshot_facade(self):
        """The tentpole acceptance: a seeded temperature>0 request emits
        identical tokens engine-vs-oneshot — both backends run the same
        sampling lane with the same key discipline."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 6)
        eng = eng_mod.Engine(params, cfg, ecfg)
        assert eng.run(reqs, max_ticks=300)["completed"] == 6
        sampled = [r for r in eng.completed if not r.params.is_greedy]
        assert len(sampled) == 3
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, ecfg.max_cache)
            assert req.out_tokens == out.tokens, \
                f"request {req.rid} diverged engine-vs-oneshot"
            assert out.finished and out.finish_reason == "length"

    def test_engine_sampling_over_shared_and_cow_pages(self):
        """Seeded sampling over adopted/CoW-forked pages: the logits under the
        sampler come from shared physical pages, and every request — sampled
        or greedy — still matches its own one-shot replay."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=64, policy="fifo",
                                    prefill_chunk=8)
        reqs = _shared_family(cfg, sampled_rids=(1, 2, 4))
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 5
        assert stats["shared_pages_adopted"] >= 4
        assert stats["cow_forks"] + stats["nowrite_adoptions"] >= 2
        assert stats["sampled_requests"] == 3
        for req in eng.completed:
            probe, out = _replay(params, cfg, req, ecfg.max_cache)
            assert req.out_tokens == out.tokens, \
                f"request {req.rid} diverged over shared/forked pages"

    def test_seeded_sampling_deterministic_across_runs(self):
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo")

        def serve():
            eng = eng_mod.Engine(params, cfg, ecfg)
            eng.run(_mixed_requests(cfg, 6), max_ticks=300)
            return {r.rid: list(r.out_tokens) for r in eng.completed}

        first, second = serve(), serve()
        assert first == second
        # and the seed actually matters: an identical-prompt request with a
        # different seed diverges somewhere in the sampled population
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
        outs = {}
        for seed in (1, 2):
            req = ServeRequest(rid=0, tokens=toks.copy(),
                               params=SamplingParams(temperature=1.2,
                                                     seed=seed,
                                                     max_new_tokens=12))
            out = api.generate(params, cfg, req, max_cache=48)
            outs[seed] = out.tokens
        assert outs[1] != outs[2], "different seeds produced identical streams"


class TestLogitsParity:
    def test_engine_logits_bitwise_match_oneshot(self):
        """The logits-level oracle: every emitted token's pre-sampling logits
        row from the engine equals one-shot ``decode.generate``'s bitwise —
        greedy and sampled requests alike, across slot-pool occupancies."""
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo",
                                    capture_logits=True)
        reqs = _mixed_requests(cfg, 5)
        eng = eng_mod.Engine(params, cfg, ecfg)
        assert eng.run(reqs, max_ticks=300)["completed"] == 5
        for req in eng.completed:
            probe, _ = _replay(params, cfg, req, ecfg.max_cache, capture=True)
            assert len(req.out_logits) == len(req.out_tokens) > 0
            assert len(probe.out_logits) == len(req.out_logits)
            for i, (a, b) in enumerate(zip(req.out_logits, probe.out_logits)):
                assert np.array_equal(a, b), \
                    f"request {req.rid} token {i}: logits differ bitwise"


class TestRetirement:
    """Per-request stop/budget retirement frees the slot's pages the same
    tick, observable through the stream and the allocator."""

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_stop_token_frees_pages_at_finish_tick(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        probe = ServeRequest(rid=0, tokens=np.arange(6, dtype=np.int32),
                             params=SamplingParams(max_new_tokens=6))
        eng_mod.Engine(params, cfg, ecfg).run([probe], max_ticks=50)
        stop = probe.out_tokens[2]

        req = ServeRequest(rid=1, tokens=np.arange(6, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=6,
                                                 stop=(stop,)))
        eng = eng_mod.Engine(params, cfg, ecfg)
        finish_out = None
        for out in eng.stream([req], max_ticks=50):
            if out.finished:
                finish_out = out
                # pages must already be back on the free list THIS tick
                assert eng.alloc.pages_in_use == 0, \
                    "stop retirement did not free pages at its tick"
        assert finish_out is not None and finish_out.finish_reason == "stop"
        assert req.out_tokens == probe.out_tokens[:3]
        assert finish_out.finish_tick == req.finish_tick
        assert finish_out.latency_ticks == req.latency
        assert finish_out.wall_latency_s is not None \
            and finish_out.wall_latency_s >= 0

    def test_stop_retirement_unblocks_page_backpressure(self, dense):
        """The freed-at-the-right-tick claim end to end: with pages for one
        request in flight, the second admits exactly when the first's stop
        token retires it — tokens earlier than its max_new_tokens would."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=32, page_size=16,
                                    num_pages=3, policy="fifo",
                                    admission_mode="reserve")  # 2 usable
        probe = ServeRequest(rid=0, tokens=np.arange(10, dtype=np.int32),
                             params=SamplingParams(max_new_tokens=8))
        eng_mod.Engine(params, cfg, ecfg).run([probe], max_ticks=60)
        stop = probe.out_tokens[3]            # stops 4 tokens in, not 8

        def reqs():
            return [
                ServeRequest(rid=0, tokens=np.arange(10, dtype=np.int32),
                             params=SamplingParams(max_new_tokens=8,
                                                   stop=(stop,))),
                ServeRequest(rid=1, tokens=np.arange(10, dtype=np.int32) + 1,
                             params=SamplingParams(max_new_tokens=4),
                             arrival=1),
            ]
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs(), max_ticks=100)
        assert stats["completed"] == 2
        r0, r1 = sorted(eng.completed, key=lambda r: r.rid)
        assert r0.finish_reason == "stop" and len(r0.out_tokens) == 4
        assert r1.admit_tick == r0.finish_tick + 1, \
            "second request did not admit right after the stop freed pages"

    def test_max_new_tokens_is_per_request(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo")
        reqs = [ServeRequest(rid=i, tokens=np.arange(6, dtype=np.int32),
                             params=SamplingParams(max_new_tokens=2 + 3 * i))
                for i in range(3)]
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=60)
        assert stats["completed"] == 3
        for i, req in enumerate(sorted(eng.completed, key=lambda r: r.rid)):
            assert len(req.out_tokens) == 2 + 3 * i
            assert req.finish_reason == "length"


class TestStreamAPI:
    @pytest.fixture(scope="class")
    def dense(self):
        cfg = _smoke_cfg("smollm-360m")
        return cfg, _params(cfg)

    def test_deltas_concatenate_to_full_stream(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 4, stagger=2)
        eng = eng_mod.Engine(params, cfg, ecfg)
        deltas: dict = {}
        finished = {}
        for out in eng.stream(reqs, max_ticks=300):
            deltas.setdefault(out.rid, []).extend(out.new_tokens)
            if out.finished:
                finished[out.rid] = out
            assert out.tokens == deltas[out.rid], \
                "cumulative tokens disagree with concatenated deltas"
        assert len(finished) == 4
        for req in eng.completed:
            assert deltas[req.rid] == req.out_tokens
            out = finished[req.rid]
            assert out.finish_reason == "length"
            assert out.admit_tick == req.admit_tick
            assert out.latency_ticks == req.latency
            assert out.deadline_met is not None
        assert eng.stats()["completed"] == 4

    def test_rejected_request_reported_in_stream(self, dense):
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=16)
        big = ServeRequest(rid=0, tokens=np.arange(12, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=8))
        ok = ServeRequest(rid=1, tokens=np.arange(6, dtype=np.int32),
                          params=SamplingParams(max_new_tokens=4))
        eng = eng_mod.Engine(params, cfg, ecfg)
        outs = list(eng.stream([big, ok], max_ticks=60))
        rej = [o for o in outs if o.finish_reason == "rejected"]
        assert len(rej) == 1 and rej[0].rid == 0 and rej[0].finished
        assert rej[0].tokens == []
        assert [o for o in outs if o.rid == 1 and o.finished]

    def test_pre_submitted_rejection_reported_in_stream(self, dense):
        """submit() before stream(): the refusal is still reported (once)."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=16)
        big = ServeRequest(rid=7, tokens=np.arange(12, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=8))
        eng = eng_mod.Engine(params, cfg, ecfg)
        eng.submit(big)
        outs = list(eng.stream([], max_ticks=10))
        assert [o.rid for o in outs if o.finish_reason == "rejected"] == [7]
        # a second stream does not re-report it
        assert not list(eng.stream([], max_ticks=10))

    def test_backstop_reports_timeout_outputs(self, dense):
        """Requests still queued or in-flight when max_ticks fires get a
        terminal finish_reason='timeout' output (finished=False), so every
        submission's fate appears in the stream."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=1, max_cache=48, policy="fifo")
        reqs = [ServeRequest(rid=i, tokens=np.arange(6, dtype=np.int32),
                             params=SamplingParams(max_new_tokens=20))
                for i in range(2)]
        eng = eng_mod.Engine(params, cfg, ecfg)
        outs = list(eng.stream(reqs, max_ticks=3))
        timeouts = {o.rid: o for o in outs if o.finish_reason == "timeout"}
        assert set(timeouts) == {0, 1}        # in-flight AND still-queued
        assert all(not o.finished for o in timeouts.values())
        assert timeouts[0].tokens == reqs[0].out_tokens  # partial progress
        assert timeouts[1].tokens == []
        assert not [o for o in outs if o.finished]

    def test_deadline_overrides_engine_budget(self, dense):
        """A request's own wall-clock deadline drives its goodput accounting:
        the same completion is in-budget under the engine's tick bar but
        misses its declared per-request deadline (1 ns — unmeetable by
        construction, so the test never races the real clock)."""
        cfg, params = dense
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo",
                                    latency_budget=40.0)
        strict = ServeRequest(rid=0, tokens=np.arange(6, dtype=np.int32),
                              params=SamplingParams(max_new_tokens=8),
                              deadline=1e-9)
        lax = ServeRequest(rid=1, tokens=np.arange(6, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=8))
        eng = eng_mod.Engine(params, cfg, ecfg)
        finished = {o.rid: o for o in eng.stream([strict, lax], max_ticks=60)
                    if o.finished}
        assert finished[0].deadline_met is False
        assert finished[1].deadline_met is True
        stats = eng.stats()
        assert stats["deadline_requests"] == 1
        assert stats["goodput"] == 0.5          # strict one missed its bar


class TestLogprobs:
    """SamplingParams.logprobs: each chosen token's logprob under the raw
    model distribution (before temperature), computed in-step — engine and
    one-shot facade must agree on every lane kind."""

    def test_engine_logprobs_match_oneshot_facade(self):
        import dataclasses
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=3, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 4)
        for r in reqs:
            r.params = dataclasses.replace(r.params, logprobs=True)
        eng = eng_mod.Engine(params, cfg, ecfg)
        stats = eng.run(reqs, max_ticks=300)
        assert stats["completed"] == 4 and stats["sampled_requests"] == 2
        for req in eng.completed:
            assert len(req.out_logprobs) == len(req.out_tokens)
            assert all(lp <= 0.0 for lp in req.out_logprobs)
            probe, out = _replay(params, cfg, req, ecfg.max_cache)
            assert req.out_tokens == out.tokens
            assert out.logprobs is not None and out.new_logprobs == out.logprobs
            np.testing.assert_allclose(req.out_logprobs, out.logprobs,
                                       atol=1e-5)

    def test_logprobs_off_by_default(self):
        cfg = _smoke_cfg("smollm-360m")
        params = _params(cfg)
        ecfg = eng_mod.EngineConfig(num_slots=2, max_cache=48, policy="fifo")
        reqs = _mixed_requests(cfg, 2)
        eng = eng_mod.Engine(params, cfg, ecfg)
        assert eng.run(reqs, max_ticks=300)["completed"] == 2
        for req in eng.completed:
            assert req.out_logprobs == []
            probe, out = _replay(params, cfg, req, ecfg.max_cache)
            assert out.logprobs is None and out.new_logprobs is None
