"""Per-arch smoke tests (reduced configs, one fwd/train step, shapes + no NaNs)
plus the numeric oracles: SSD vs recurrence, MoE dispatch vs dense, chunked
attention vs dense, decode vs full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import layers, model, moe, ssm

jax.config.update("jax_platform_name", "cpu")

ALL_ARCHS = sorted(configs.ARCHS)


def _batch(cfg, key, b=2, s=32):
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, s, cfg.frontend_dim))
    return batch


def _bias(cfg):
    return (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_and_grad(self, arch):
        cfg = configs.get_config(arch).smoke()
        key = jax.random.PRNGKey(0)
        params = model.init_params(key, cfg)
        batch = _batch(cfg, key)

        def loss_fn(p):
            return model.train_loss(p, cfg, batch, router_bias=_bias(cfg)).loss

        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
        assert jnp.isfinite(loss), arch
        # a healthy init sits near uniform cross-entropy
        assert abs(float(loss) - np.log(cfg.vocab_size)) < 1.0
        gnorms = [float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads)]
        assert all(np.isfinite(gnorms)), arch
        assert max(gnorms) > 0, "all-zero gradients"

    def test_full_config_instantiable_abstractly(self, arch):
        """The FULL config is exercised via eval_shape only (no allocation)."""
        cfg = configs.get_config(arch)
        abs_params = jax.eval_shape(
            lambda: model.init_params(jax.random.PRNGKey(0), cfg))
        n = sum(int(x.size) for x in jax.tree.leaves(abs_params))
        expected = {  # sanity bands on total params
            "smollm-360m": (3e8, 4.5e8), "minicpm-2b": (2e9, 3.3e9),
            "gemma-7b": (7e9, 10e9), "qwen3-4b": (3e9, 5e9),
            "paligemma-3b": (2e9, 3.5e9), "granite-moe-3b-a800m": (2.5e9, 4.5e9),
            "kimi-k2-1t-a32b": (0.9e12, 1.3e12),
            "recurrentgemma-9b": (7e9, 11e9), "mamba2-130m": (1e8, 2e8),
            "musicgen-medium": (1e9, 2e9),
        }[arch]
        assert expected[0] <= n <= expected[1], f"{arch}: {n:.3e} params"


class TestNumericOracles:
    def test_ssd_chunked_matches_recurrence(self):
        key = jax.random.PRNGKey(42)
        ks = jax.random.split(key, 5)
        b, s, h, p, n = 2, 48, 3, 8, 16
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a_log = jax.random.normal(ks[2], (h,)) * 0.5
        B_ = jax.random.normal(ks[3], (b, s, n))
        C_ = jax.random.normal(ks[4], (b, s, n))
        y1 = ssm.ssd_chunked(x, dt, a_log, B_, C_, chunk=16)
        y2 = ssm.ssd_reference(x, dt, a_log, B_, C_)
        np.testing.assert_allclose(y1, y2, rtol=3e-4, atol=3e-4)

    @pytest.mark.parametrize("groups", [1, 4])
    def test_moe_dispatch_matches_dense(self, groups):
        cfg = dataclasses.replace(
            configs.get_config("granite-moe-3b-a800m").smoke(),
            capacity_factor=8.0, dispatch_groups=groups)
        key = jax.random.PRNGKey(0)
        params = moe.init_moe(key, cfg)
        x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
        bias = jnp.zeros((cfg.num_experts,))
        y, stats = moe.moe_ffn(params, x, cfg, bias)
        y_ref = moe.moe_ffn_reference(params, x, cfg, bias)
        assert float(stats.drop_frac) == 0.0
        np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)

    def test_moe_capacity_drops_tokens(self):
        cfg = dataclasses.replace(
            configs.get_config("granite-moe-3b-a800m").smoke(),
            capacity_factor=0.25)
        key = jax.random.PRNGKey(0)
        params = moe.init_moe(key, cfg)
        x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
        _, stats = moe.moe_ffn(params, x, cfg, jnp.zeros((cfg.num_experts,)))
        assert float(stats.drop_frac) > 0.0

    @pytest.mark.parametrize("window,prefix", [(None, None), (512, None),
                                               (None, 100)])
    def test_chunked_attention_matches_dense(self, window, prefix):
        cfg = dataclasses.replace(configs.get_config("smollm-360m").smoke(),
                                  num_heads=4, num_kv_heads=2, head_dim=16)
        key = jax.random.PRNGKey(0)
        b, s = 2, 2560        # > _CHUNK_THRESHOLD and a non-power-of-two chunk fit
        q = jax.random.normal(key, (b, s, 4, 16))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, 2, 16))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 2, 16))
        plen = None if prefix is None else jnp.asarray(prefix)
        ref = layers._sdpa(q, k, v, layers.causal_mask(s, s, window, plen), cfg)
        chk = layers._sdpa_chunked(q, k, v, cfg, window, plen)
        np.testing.assert_allclose(chk, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen3-4b", "mamba2-130m",
                                  "recurrentgemma-9b", "granite-moe-3b-a800m",
                                  "musicgen-medium", "gemma-7b"])
class TestDecodeConsistency:
    def test_prefill_plus_decode_matches_full_forward(self, arch):
        cfg = configs.get_config(arch).smoke()
        if cfg.num_experts:
            cfg = dataclasses.replace(cfg, capacity_factor=8.0)
        key = jax.random.PRNGKey(7)
        params = model.init_params(key, cfg)
        b, s = 2, 33
        batch = _batch(cfg, key, b, s)
        bias = _bias(cfg)

        from repro.models.model import _head, _inputs_train
        from repro.models import transformer
        x, plen = _inputs_train(params, cfg, batch)
        xf, _, _, _ = transformer.apply_stack(params["stack"], x, cfg, bias=bias)
        logits_full = _head(params, cfg, xf)[:, -1]

        cache = model.init_cache(cfg, b, 64)
        pre = {k: (v[:, :-1] if k in ("tokens", "frames") else v)
               for k, v in batch.items()}
        _, cache = model.prefill(params, cfg, pre, cache, router_bias=bias)
        dec = {"token": batch["tokens"][:, -1:]}
        if cfg.family == "audio":
            dec["frame"] = batch["frames"][:, -1:]
        logits_dec, _ = model.decode_step(params, cfg, dec, cache,
                                          router_bias=bias)
        np.testing.assert_allclose(logits_dec[:, 0], logits_full,
                                   rtol=3e-3, atol=3e-3)
