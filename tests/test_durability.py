"""Durable serving: write-ahead journal + warm snapshots (serve.durability).

Three layers, cheapest first. Journal semantics are model-free and run in
milliseconds: record framing, group-commit fsync tracking, torn-tail
truncation at *every* byte offset (the hypothesis churn test — any crash
point must recover to the exact fold of the records wholly before it, zero
duplicated, zero lost synced finishes). The checkpoint tests pin the
atomicity fix: the destination directory is fsync'd *after* the rename, and
``restore_raw`` round-trips dynamic-shaped snapshots. The model tests drive
real fleets through a full power loss and pin the tentpole invariant:
``run_durable`` finishes the trace with zero lost rids, zero duplicated
completions, and per-request token streams bitwise identical to the
fault-free run — warm (snapshot) restarts re-prefilling no more than cold
(journal-only) ones — plus the silent-corruption guard: a NaN-poisoned KV
page retires its lane with ``finish_reason="corrupted"`` instead of
streaming garbage.
"""
import os

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.dist import checkpoint
from repro.models import model, transformer
from repro.serve import durability
from repro.serve import engine as eng_mod
from repro.serve import router as rt_mod
from repro.serve import traces
from repro.serve.api import SamplingParams, ServeRequest
from repro.serve.faults import FaultInjector, FaultPlan, PowerLoss
from repro.serve.paging import PageAllocator

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense():
    cfg = configs.get_config("smollm-360m").smoke()
    return cfg, model.init_params(jax.random.PRNGKey(0), cfg)


def _ecfg(**kw):
    base = dict(num_slots=2, max_cache=96, page_size=16, prefill_chunk=8,
                policy="immune", num_classes=3, latency_budget=96.0,
                pin_pages=8, num_pages=2 * (96 // 16) + 1 + 8)
    base.update(kw)
    return eng_mod.EngineConfig(**base)


def _fleet_trace(cfg, **kw):
    base = dict(tenants=2, num_requests=18, prefix_len=48, suffix_lens=(4,),
                decode_lens=(6,), hot_frac=0.9, burst_every=4, burst_size=3,
                seed=0)
    base.update(kw)
    return traces.fleet_trace(cfg, **base)


def _req(rid, plen=5, deadline=None, **kw):
    base = dict(max_new_tokens=4, seed=rid)
    base.update(kw)
    return ServeRequest(rid=rid, tokens=np.arange(plen, dtype=np.int32),
                        params=SamplingParams(**base), rclass=rid % 2,
                        arrival=rid, deadline=deadline)


def _tokens_by_rid(router):
    return {r.rid: list(r.out_tokens) for r in router.completed}


# ---------------------------------------------------------------------------
# journal semantics (model-free)
# ---------------------------------------------------------------------------
class TestJournal:
    def test_round_trip(self, tmp_path):
        p = str(tmp_path / "wal")
        j = durability.RequestJournal(p)
        r = _req(3, deadline=1.5)
        j.log_submit(r)
        j.log_emit(3, 11)
        j.log_emit(3, 12)
        j.log_finish(3, "stop", 9)
        j.close()
        j2 = durability.RequestJournal(p)
        assert set(j2.state) == {3}
        s = j2.state[3]
        assert s["tokens"] == list(range(5)) and s["out"] == [11, 12]
        assert s["fin"] == "stop" and s["fin_tick"] == 9
        assert s["rclass"] == 1 and s["arrival"] == 3
        assert s["deadline"] == 1.5
        assert SamplingParams(**s["params"]) == r.params

    def test_group_commit_cadence(self, tmp_path):
        j = durability.RequestJournal(str(tmp_path / "wal"), sync_every=3)
        j.log_submit(_req(0))              # submits always fsync
        base = j.syncs
        j.log_emit(0, 1)
        assert j.commit(0) is True         # first commit establishes the epoch
        j.log_emit(0, 2)
        assert j.commit(1) is False        # within the window: buffered
        j.log_emit(0, 3)
        assert j.commit(2) is False
        j.log_emit(0, 4)
        assert j.commit(3) is True         # 3 ticks elapsed -> one fsync
        assert j.syncs == base + 2
        assert j.commit(4) is False        # nothing dirty: no-op

    def test_power_loss_drops_unsynced_only(self, tmp_path):
        p = str(tmp_path / "wal")
        j = durability.RequestJournal(p, sync_every=100)
        j.log_submit(_req(1))
        j.log_emit(1, 7)
        j.commit(0)                        # epoch-setting sync covers tok 7
        j.log_emit(1, 8)                   # buffered, never fsync'd
        j.log_finish(1, "stop", 5)
        j.simulate_power_loss()
        j2 = durability.RequestJournal(p)
        assert j2.state[1]["out"] == [7]   # 8 and the finish died in the cache
        assert j2.state[1]["fin"] is None
        with pytest.raises(ValueError):
            j.log_emit(1, 9)               # dead journal refuses writes

    def test_submit_fsync_survives_power_loss(self, tmp_path):
        p = str(tmp_path / "wal")
        j = durability.RequestJournal(p, sync_every=100)
        j.log_submit(_req(5))
        j.simulate_power_loss()            # no commit() ever ran
        assert 5 in durability.RequestJournal(p).state

    def test_torn_tail_truncated(self, tmp_path):
        p = str(tmp_path / "wal")
        j = durability.RequestJournal(p)
        j.log_submit(_req(2))
        j.close()
        size = os.path.getsize(p)
        with open(p, "ab") as f:           # torn header + garbage payload
            f.write(b"\xff\xff\x00\x00abcdef")
        j2 = durability.RequestJournal(p)
        assert j2.truncated_bytes == 10 and j2.records == 1
        assert os.path.getsize(p) == size  # file physically truncated
        # corrupt the *checksum* of a complete record: also a torn tail
        with open(p, "r+b") as f:
            f.seek(size - 1)
            last = f.read(1)
            f.seek(size - 1)
            f.write(bytes([last[0] ^ 0xFF]))
        j3 = durability.RequestJournal(p)
        assert j3.records == 0 and j3.state == {}

    @hypothesis.given(cut=st.integers(0, 600))
    @hypothesis.settings(deadline=None, max_examples=60)
    def test_churn_any_crash_point_recovers_consistent_prefix(
            self, cut, tmp_path):
        """Truncate the journal at an arbitrary byte (mid-record included):
        recovery must equal the fold of exactly the records wholly before the
        cut — a consistent prefix with zero duplicated and zero lost
        finished rids."""
        p = str(tmp_path / f"wal{cut}")
        if os.path.exists(p):        # repeated draws must not share a journal
            os.remove(p)
        j = durability.RequestJournal(p)
        ends, recs = [], []

        def put(kind, *a):
            getattr(j, kind)(*a)
            j.sync()
            ends.append(os.path.getsize(p))
            recs.append((kind, a))

        put("log_submit", _req(0))
        put("log_emit", 0, 10)
        put("log_submit", _req(1, plen=3))
        put("log_emit", 0, 11)
        put("log_emit", 1, 20)
        put("log_finish", 0, "stop", 4)
        put("log_emit", 1, 21)
        put("log_finish", 1, "length", 6)
        j.close()
        total = os.path.getsize(p)
        cut = min(cut, total)
        with open(p, "r+b") as f:
            f.truncate(cut)
        got = durability.RequestJournal(p).state
        # expected: fold of the records whose last byte is <= cut
        want: dict = {}
        for (kind, a), end in zip(recs, ends):
            if end > cut:
                break
            if kind == "log_submit":
                want[a[0].rid] = {"out": [], "fin": None}
            elif kind == "log_emit":
                want[a[0]]["out"].append(a[1])
            else:
                want[a[0]]["fin"] = a[1]
        assert set(got) == set(want)
        for rid, w in want.items():
            assert got[rid]["out"] == w["out"]       # no dup, no reorder
            assert got[rid]["fin"] == w["fin"]       # no lost synced finish


# ---------------------------------------------------------------------------
# checkpoint atomicity fix + raw restore
# ---------------------------------------------------------------------------
class TestCheckpointDurability:
    def test_dir_fsync_after_rename(self, tmp_path, monkeypatch):
        """The classic rename-without-dirsync gap: the parent directory must
        be fsync'd *after* the atomic rename lands, else a power loss can
        roll the directory entry back and lose a checkpoint already reported
        durable."""
        events = []
        real_rename, real_open = os.rename, os.open
        real_fsync = os.fsync
        dirs_opened = {}

        def spy_rename(src, dst):
            events.append(("rename", dst))
            return real_rename(src, dst)

        def spy_open(path, flags, *a, **kw):
            fd = real_open(path, flags, *a, **kw)
            if os.path.isdir(path):
                dirs_opened[fd] = path
            return fd

        def spy_fsync(fd):
            if fd in dirs_opened:
                events.append(("dirsync", dirs_opened[fd]))
            return real_fsync(fd)

        monkeypatch.setattr(os, "rename", spy_rename)
        monkeypatch.setattr(os, "open", spy_open)
        monkeypatch.setattr(os, "fsync", spy_fsync)
        d = str(tmp_path / "ck")
        checkpoint.save(d, [np.arange(4)], step=1)
        renames = [i for i, (k, _) in enumerate(events) if k == "rename"]
        dirsyncs = [i for i, (k, v) in enumerate(events)
                    if k == "dirsync" and v == d]
        assert renames and dirsyncs
        assert max(dirsyncs) > max(renames), \
            f"no destination-dir fsync after the final rename: {events}"

    def test_restore_raw_dynamic_shapes(self, tmp_path):
        d = str(tmp_path / "ck")
        leaves = [np.frombuffer(b'{"a":1}', np.uint8),
                  np.ones((2, 3), np.float32),
                  np.arange(5, dtype=np.int64)]
        checkpoint.save(d, leaves, step=7)
        got, step = checkpoint.restore_raw(d)
        assert step == 7 and len(got) == 3
        for a, b in zip(leaves, got):
            np.testing.assert_array_equal(a, b)
        assert checkpoint.restore_raw(str(tmp_path / "none")) == (None, 0)

    def test_snapshot_blob_round_trip(self, tmp_path):
        d = str(tmp_path / "snap")
        meta = {"tick": 9, "replicas": [{"forest": []}]}
        kv = [np.full((1, 4, 2, 3), 0.5, np.float32)]
        durability.save_snapshot(d, 9, meta, kv)
        got_meta, got_kv, step = durability.load_snapshot(d)
        assert step == 9 and got_meta == meta
        np.testing.assert_array_equal(got_kv[0], kv[0])
        assert durability.load_snapshot(str(tmp_path / "none")) \
            == (None, [], 0)


# ---------------------------------------------------------------------------
# pinned-forest export/import (model-free allocator round trip)
# ---------------------------------------------------------------------------
class TestPinnedForest:
    def _alloc(self):
        return PageAllocator(12, 4, 2, 6, share_prefix=True, pin_pages=6,
                             num_classes=2, require_reservation=False)

    def test_export_import_round_trip(self):
        a = self._alloc()
        toks = np.arange(8, dtype=np.int32)          # two full pages
        a.ensure(0, 2)
        a.register_prefix(0, toks, rclass=1)
        a.release(0)                                  # refcount 0 -> pinned
        assert a.pages_pinned == 2
        forest = a.export_pinned()
        assert [e["parent"] for e in forest] == [-1, 0]
        b = self._alloc()
        placed = b.import_pinned(forest)
        assert len(placed) == 2 and b.pages_pinned == 2
        assert b.pinned_chain_keys() == a.pinned_chain_keys()
        # match needs one token past the chain: the last prompt token is
        # always recomputed, so probe with a 9-token prompt over the 8-token
        # registered prefix
        full, partial = b.match_prefix(np.arange(9, dtype=np.int32))
        assert len(full) == 2 and partial is None

    def test_import_respects_pin_budget(self):
        a = self._alloc()
        a.ensure(0, 2)
        a.register_prefix(0, np.arange(8, dtype=np.int32), rclass=0)
        a.release(0)
        b = PageAllocator(12, 4, 2, 6, share_prefix=True, pin_pages=1)
        placed = b.import_pinned(a.export_pinned())
        assert len(placed) == 1 and b.pages_pinned == 1


# ---------------------------------------------------------------------------
# poweroff plan grammar + injector signal (model-free)
# ---------------------------------------------------------------------------
class TestPoweroffPlan:
    def test_parse_and_pairing(self):
        plan = FaultPlan.parse("poweroff@12 restart@16 crash@3:r0")
        kinds = [e.kind for e in plan]
        assert kinds == ["crash", "poweroff", "restart"]
        assert all(e.replica == -1 for e in plan if e.kind != "crash")
        with pytest.raises(ValueError):
            FaultPlan.parse("poweroff@5:r1")          # fleet-wide: no :rN
        with pytest.raises(ValueError):
            FaultPlan.parse("restart@9")              # restart without poweroff
        with pytest.raises(ValueError):
            FaultPlan.parse("poweroff@5 poweroff@9")  # double off, no restart
        FaultPlan.parse("poweroff@5 restart@7 poweroff@9")  # re-off is fine

    def test_injector_raises_power_loss(self):
        class _Rt:
            tick = 12
            engines = [object()]
        inj = FaultInjector(FaultPlan.parse("poweroff@12 restart@16"))
        with pytest.raises(PowerLoss) as ei:
            inj.begin_tick(_Rt())
        assert ei.value.tick == 12 and ei.value.restart_tick == 16
        assert inj.stats()["poweroffs"] == 1
        # past the poweroff tick (post-recovery): restart is a no-op marker
        _Rt.tick = 16
        inj2 = FaultInjector(FaultPlan.parse("poweroff@12 restart@16"))
        inj2.begin_tick(_Rt())


# ---------------------------------------------------------------------------
# full power-loss recovery (model)
# ---------------------------------------------------------------------------
class TestPowerLossRecovery:
    def _factory(self, params, cfg, plan_spec, replicas=2, policy="immune"):
        def make():
            inj = FaultInjector(FaultPlan.parse(plan_spec))
            fleet = [eng_mod.Engine(params, cfg, _ecfg())
                     for _ in range(replicas)]
            return rt_mod.Router(fleet, rt_mod.RouterConfig(policy=policy),
                                 injector=inj)
        return make

    def test_poweroff_recover_bitwise_and_exactly_once(self, dense, tmp_path):
        cfg, params = dense
        ref_rt = rt_mod.Router([eng_mod.Engine(params, cfg, _ecfg())
                                for _ in range(2)],
                               rt_mod.RouterConfig(policy="immune"))
        ref = ref_rt.run(_fleet_trace(cfg))
        ref_toks = _tokens_by_rid(ref_rt)
        off = max(2, ref["ticks"] // 2)
        spec = f"poweroff@{off} restart@{off + 4}"
        rt, stats = durability.run_durable(
            self._factory(params, cfg, spec), _fleet_trace(cfg),
            str(tmp_path / "wal"), snapshot_dir=str(tmp_path / "snap"),
            snapshot_every=2)
        assert stats["restarts"] == 1
        got = _tokens_by_rid(rt)
        # zero lost rids, zero duplicates, bitwise-identical streams
        assert got == ref_toks
        assert len(rt.completed) == len({r.rid for r in rt.completed})
        assert stats["completed"] == ref["completed"]
        d = stats["durability"]
        assert d["recovered_finished"] + d["recovered_open"] > 0
        assert d["journal"]["truncated_bytes"] == 0  # clean group commits
        # every demanded request is accounted
        assert stats["completed"] + stats["shed"] + stats["rejected"] \
            + stats["corrupted"] + stats["unserved"] + stats["failed"] \
            == len(_fleet_trace(cfg))

    def test_resubmission_after_finish_is_deduped(self, dense, tmp_path):
        cfg, params = dense
        trace = _fleet_trace(cfg, num_requests=6)
        rt, stats = durability.run_durable(
            self._factory(params, cfg, "poweroff@4 restart@6"), trace,
            str(tmp_path / "wal"))
        journal = durability.RequestJournal(str(tmp_path / "wal"))
        rt2 = rt_mod.Router([eng_mod.Engine(params, cfg, _ecfg())
                             for _ in range(2)],
                            rt_mod.RouterConfig(policy="immune"))
        rt2.recover(journal, None)
        done_before = len(rt2.completed)
        out = rt2.run(_fleet_trace(cfg, num_requests=6))  # full re-drive
        assert rt2.dedup_drops == done_before == 6
        assert out["completed"] == 6                      # still exactly once
        assert _tokens_by_rid(rt2) == _tokens_by_rid(rt)

    def test_warm_restart_prefills_no_more_than_cold(self, dense, tmp_path):
        cfg, params = dense
        ref_rt = rt_mod.Router([eng_mod.Engine(params, cfg, _ecfg())
                                for _ in range(2)],
                               rt_mod.RouterConfig(policy="immune"))
        ref = ref_rt.run(_fleet_trace(cfg))
        off = (2 * ref["ticks"]) // 3
        spec = f"poweroff@{off} restart@{off + 4}"

        def run(snap):
            d = tmp_path / ("warm" if snap else "cold")
            d.mkdir()
            rt, stats = durability.run_durable(
                self._factory(params, cfg, spec), _fleet_trace(cfg),
                str(d / "wal"),
                snapshot_dir=str(d / "snap") if snap else None,
                snapshot_every=2)
            return rt, stats, sum(e.prefill_tokens for e in rt.engines)

        warm_rt, warm, warm_pf = run(True)
        cold_rt, cold, cold_pf = run(False)
        assert _tokens_by_rid(warm_rt) == _tokens_by_rid(cold_rt) \
            == _tokens_by_rid(ref_rt)
        assert warm["durability"]["recovered_pinned_pages"] > 0
        assert cold["durability"]["recovered_pinned_pages"] == 0
        # the pinned forest came back with its K/V: the warm fleet re-prefills
        # strictly less than the cold one (the 0.5x bar is gated, with a
        # bench-sized workload, in benchmarks/serve_engine.py durability)
        assert warm_pf < cold_pf


# ---------------------------------------------------------------------------
# silent-corruption guard (model)
# ---------------------------------------------------------------------------
class TestCorruptionGuard:
    def test_nan_page_retires_lane_as_corrupted(self, dense):
        cfg, params = dense
        eng = eng_mod.Engine(params, cfg, _ecfg(prefix_sharing=False))
        reqs = [ServeRequest(rid=i,
                             tokens=np.random.default_rng(i).integers(
                                 0, cfg.vocab_size, size=8).astype(np.int32),
                             params=SamplingParams(max_new_tokens=8),
                             rclass=i % 2, arrival=0) for i in range(2)]
        for r in reqs:
            eng.submit(r)
        while not all(eng.active_host[:2]) and eng.tick < 50:
            eng.step()
        victim = 0
        page = eng.alloc.owned(victim)[0]

        def poison(kind, leaf):
            if kind in ("attn", "moe"):
                return {"k": leaf["k"].at[:, page].set(jnp.nan),
                        "v": leaf["v"]}
            return leaf

        eng.pool = {"layers": transformer.map_block_caches(
            cfg, poison, eng.pool["layers"]), "pos": eng.pool["pos"]}
        for _ in range(3):
            eng.step()
        assert len(eng.corrupted) == 1
        bad = eng.corrupted[0]
        assert bad.rid == reqs[victim].rid
        assert bad.finish_reason == "corrupted" and bad.finish_tick >= 0
        assert eng.slots[victim] is None              # lane freed
        # the healthy lane keeps decoding to completion with finite tokens
        for _ in range(60):
            if not any(r is not None for r in eng.slots) and not eng.queue:
                break
            eng.step()
        assert len(eng.completed) == 1
        stats = eng.stats()
        assert stats["corrupted"] == 1
        assert stats["completed"] + stats["corrupted"] == 2

    def test_stream_reports_corrupted(self, dense):
        cfg, params = dense
        eng = eng_mod.Engine(params, cfg, _ecfg(prefix_sharing=False))
        req = ServeRequest(rid=0, tokens=np.arange(8, dtype=np.int32),
                           params=SamplingParams(max_new_tokens=8), arrival=0)
        outs = []
        poisoned = False
        for out in eng.stream([req], max_ticks=80):
            outs.append(out)
            if not poisoned and eng.active_host[0]:
                page = eng.alloc.owned(0)[0]

                def poison(kind, leaf):
                    if kind in ("attn", "moe"):
                        return {"k": leaf["k"].at[:, page].set(jnp.nan),
                                "v": leaf["v"]}
                    return leaf

                eng.pool = {"layers": transformer.map_block_caches(
                    cfg, poison, eng.pool["layers"]), "pos": eng.pool["pos"]}
                poisoned = True
        finals = [o for o in outs if o.finished]
        assert len(finals) == 1 and finals[0].finish_reason == "corrupted"
