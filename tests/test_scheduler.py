"""Immune straggler scheduler: beats static under heterogeneity, detects failures,
revives recovered workers, and does not oscillate."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sch

jax.config.update("jax_platform_name", "cpu")


def _hetero_trace(t=200, w=8, seed=0, straggler_slow=0.25):
    rng = np.random.default_rng(seed)
    speeds = np.ones((t, w)) + 0.05 * rng.standard_normal((t, w))
    speeds[:, 0] *= straggler_slow          # persistent straggler
    return jnp.asarray(np.clip(speeds, 1e-3, None), jnp.float32)


class TestStragglerMitigation:
    def test_beats_static_with_straggler(self):
        trace = _hetero_trace()
        t_imm = float(jnp.sum(sch.simulate(trace)))
        t_static = float(jnp.sum(sch.simulate(trace, static=True)))
        assert t_imm < 0.55 * t_static, (t_imm, t_static)

    def test_matches_static_when_homogeneous(self):
        trace = _hetero_trace(straggler_slow=1.0)
        t_imm = float(jnp.sum(sch.simulate(trace)))
        t_static = float(jnp.sum(sch.simulate(trace, static=True)))
        assert t_imm < 1.1 * t_static

    def test_fraction_tracks_speed(self):
        state = sch.init_scheduler(4)
        speeds = jnp.asarray([2.0, 1.0, 1.0, 1.0])
        for _ in range(100):
            state = sch.observe(state, speeds)
        assert float(state.frac[0]) > 1.5 * float(state.frac[1])

    def test_no_oscillation(self):
        state = sch.init_scheduler(4)
        speeds = jnp.asarray([2.0, 1.0, 1.0, 1.0])
        hist = []
        for _ in range(200):
            state = sch.observe(state, speeds)
            hist.append(np.asarray(state.frac))
        tail = np.stack(hist[-50:])
        assert tail.std(axis=0).max() < 0.01, "shard fractions oscillate"


class TestFailureAnergy:
    def test_dead_worker_anergized_and_revived(self):
        state = sch.init_scheduler(4)
        alive = jnp.asarray([1.0, 1.0, 1.0, 1.0])
        dead = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        for _ in range(80):
            state = sch.observe(state, dead)
        assert bool(state.anergic[3]), "dead worker not excluded"
        assert float(state.frac[3]) == 0.0
        np.testing.assert_allclose(float(jnp.sum(state.frac)), 1.0, rtol=1e-5)
        # recovery: worker heartbeats again for revival_steps
        for _ in range(10):
            state = sch.observe(state, alive)
        assert not bool(state.anergic[3]), "recovered worker not revived"
        for _ in range(100):
            state = sch.observe(state, alive)
        assert float(state.frac[3]) > 0.15, "revived worker got no work back"

    def test_survives_majority_failure(self):
        state = sch.init_scheduler(8)
        speeds = jnp.asarray([1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])
        for _ in range(100):
            state = sch.observe(state, speeds)
        assert int(jnp.sum(state.anergic)) == 6
        np.testing.assert_allclose(float(jnp.sum(state.frac)), 1.0, rtol=1e-5)
