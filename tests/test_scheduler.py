"""Immune straggler scheduler: beats static under heterogeneity, detects failures,
revives recovered workers, does not oscillate — plus fleet edge cases (all-dead,
single-worker, mass revival) and the shard-fraction invariant as a property."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sch

jax.config.update("jax_platform_name", "cpu")


def _hetero_trace(t=200, w=8, seed=0, straggler_slow=0.25):
    rng = np.random.default_rng(seed)
    speeds = np.ones((t, w)) + 0.05 * rng.standard_normal((t, w))
    speeds[:, 0] *= straggler_slow          # persistent straggler
    return jnp.asarray(np.clip(speeds, 1e-3, None), jnp.float32)


class TestStragglerMitigation:
    def test_beats_static_with_straggler(self):
        trace = _hetero_trace()
        t_imm = float(jnp.sum(sch.simulate(trace)))
        t_static = float(jnp.sum(sch.simulate(trace, static=True)))
        assert t_imm < 0.55 * t_static, (t_imm, t_static)

    def test_matches_static_when_homogeneous(self):
        trace = _hetero_trace(straggler_slow=1.0)
        t_imm = float(jnp.sum(sch.simulate(trace)))
        t_static = float(jnp.sum(sch.simulate(trace, static=True)))
        assert t_imm < 1.1 * t_static

    def test_fraction_tracks_speed(self):
        state = sch.init_scheduler(4)
        speeds = jnp.asarray([2.0, 1.0, 1.0, 1.0])
        for _ in range(100):
            state = sch.observe(state, speeds)
        assert float(state.frac[0]) > 1.5 * float(state.frac[1])

    def test_no_oscillation(self):
        state = sch.init_scheduler(4)
        speeds = jnp.asarray([2.0, 1.0, 1.0, 1.0])
        hist = []
        for _ in range(200):
            state = sch.observe(state, speeds)
            hist.append(np.asarray(state.frac))
        tail = np.stack(hist[-50:])
        assert tail.std(axis=0).max() < 0.01, "shard fractions oscillate"


class TestFailureAnergy:
    def test_dead_worker_anergized_and_revived(self):
        state = sch.init_scheduler(4)
        alive = jnp.asarray([1.0, 1.0, 1.0, 1.0])
        dead = jnp.asarray([1.0, 1.0, 1.0, 0.0])
        for _ in range(80):
            state = sch.observe(state, dead)
        assert bool(state.anergic[3]), "dead worker not excluded"
        assert float(state.frac[3]) == 0.0
        np.testing.assert_allclose(float(jnp.sum(state.frac)), 1.0, rtol=1e-5)
        # recovery: worker heartbeats again for revival_steps
        for _ in range(10):
            state = sch.observe(state, alive)
        assert not bool(state.anergic[3]), "recovered worker not revived"
        for _ in range(100):
            state = sch.observe(state, alive)
        assert float(state.frac[3]) > 0.15, "revived worker got no work back"

    def test_survives_majority_failure(self):
        state = sch.init_scheduler(8)
        speeds = jnp.asarray([1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])
        for _ in range(100):
            state = sch.observe(state, speeds)
        assert int(jnp.sum(state.anergic)) == 6
        np.testing.assert_allclose(float(jnp.sum(state.frac)), 1.0, rtol=1e-5)


def _all_anergic(w: int = 4) -> sch.SchedulerState:
    return sch.init_scheduler(w)._replace(
        anergic=jnp.ones((w,), bool),
        frac=jnp.zeros((w,), jnp.float32),
        mem=jnp.zeros((w,), jnp.float32))


class TestFleetEdgeCases:
    def test_all_anergic_step_time_is_not_zero(self):
        """A fully-dead fleet must not look infinitely fast: the max over an
        empty set of live workers is inf, not 0.0."""
        t = sch.step_time(_all_anergic(), jnp.ones((4,)))
        assert float(t) == float("inf")

    def test_all_anergic_simulate_diverges(self):
        """simulate over a trace that starts all-dead accumulates inf time
        rather than claiming instant steps."""
        state = _all_anergic()
        t = sch.step_time(state, jnp.asarray([2.0, 2.0, 2.0, 2.0]))
        assert not bool(jnp.isfinite(t))
        # one worker back alive -> finite again
        state = state._replace(anergic=jnp.asarray([False, True, True, True]),
                               frac=jnp.asarray([1.0, 0.0, 0.0, 0.0]))
        assert bool(jnp.isfinite(sch.step_time(state, jnp.ones((4,)))))

    def test_single_worker_fleet(self):
        """W=1: the only worker keeps the whole share and is never anergized by
        the relative-health test, even through a dead spell."""
        state = sch.init_scheduler(1)
        for thr in (1.0, 0.5, 0.0, 0.0, 0.0, 1.0):
            state = sch.observe(state, jnp.asarray([thr]))
            assert not bool(state.anergic[0])
            np.testing.assert_allclose(float(state.frac[0]), 1.0, rtol=1e-6)
        assert float(sch.step_time(state, jnp.asarray([2.0]))) > 0.0

    def test_mass_simultaneous_revival(self):
        """Every worker anergic, then the whole fleet heartbeats: all revive in
        the same step and the shares return to a normalized distribution."""
        state = _all_anergic(4)
        cfg = sch.SchedulerConfig()
        for _ in range(cfg.revival_steps):
            state = sch.observe(state, jnp.ones((4,)))
        assert not bool(jnp.any(state.anergic)), "mass revival failed"
        frac = np.asarray(state.frac)
        assert (frac > 0).all()
        np.testing.assert_allclose(frac.sum(), 1.0, rtol=1e-5)
        np.testing.assert_allclose(frac, 0.25, atol=1e-3)


class TestSchedulerProperties:
    @hypothesis.settings(deadline=None, max_examples=25)
    @hypothesis.given(trace=st.lists(
        st.lists(st.floats(0.0, 4.0), min_size=6, max_size=6),
        min_size=1, max_size=40))
    def test_frac_nonnegative_and_normalized_over_live(self, trace):
        """For arbitrary throughput traces: frac >= 0 everywhere, anergic
        workers hold exactly 0, and the live shares sum to 1 (whenever anyone
        is live)."""
        state = sch.init_scheduler(6)
        for speeds in trace:
            state = sch.observe(state, jnp.asarray(speeds, jnp.float32))
            frac = np.asarray(state.frac)
            live = ~np.asarray(state.anergic)
            assert (frac >= 0.0).all(), frac
            assert (frac[~live] == 0.0).all(), frac
            if live.any():
                np.testing.assert_allclose(frac[live].sum(), 1.0, atol=1e-4)
