"""Immune MoE router: regulation balances skewed loads; baselines; anergy revival."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import router as irouter

jax.config.update("jax_platform_name", "cpu")


import functools


@functools.lru_cache(maxsize=8)
def _sim_fn(mode: str, e: int, t: int):
    """One jitted scan per (mode, e, t): the whole simulation is a single XLA
    program, reused across cases and step counts instead of dispatching
    thousands of tiny host-side ops."""
    cfg = irouter.RouterConfig(mode=mode)
    skew = jnp.linspace(2.0, 0.0, e)[None, :]          # expert 0 strongly preferred

    def body(state, i):
        logits = skew + 0.5 * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(0), i), (t, e))
        idx, gates, probs = irouter.route(logits, state.bias, k=2)
        load = irouter.load_fractions(idx, e)
        return irouter.update_router_state(state, load, cfg), irouter.load_cv(load)

    @jax.jit
    def run(steps_arr):
        return jax.lax.scan(body, irouter.init_router_state(e), steps_arr)

    return run


def _simulate(mode: str, steps: int = 400, e: int = 8, t: int = 512, seed: int = 0):
    """Feed a router whose raw logits are *persistently skewed* toward expert 0 and
    watch whether the balancing state evens out the realized loads."""
    base = seed * 1_000_003
    state, cvs = _sim_fn(mode, e, t)(jnp.arange(base, base + steps, dtype=jnp.int32))
    return np.asarray(cvs), state


class TestImmuneRouter:
    def test_balances_skewed_load(self):
        cvs, state = _simulate("immune")
        assert cvs[-1] < 0.25, f"final load CV {cvs[-1]} too high"
        assert cvs[-1] < cvs[0] * 0.3, "no improvement over unregulated start"

    def test_beats_or_matches_none(self):
        cvs_imm, _ = _simulate("immune")
        cvs_none, _ = _simulate("none")
        assert cvs_imm[-50:].mean() < cvs_none[-50:].mean() * 0.5

    def test_no_oscillation_at_steady_state(self):
        cvs, _ = _simulate("immune", steps=600)
        tail = cvs[-100:]
        assert tail.std() < 0.08, "limit cycle in the regulated loads"

    def test_sign_baseline_also_balances(self):
        cvs, _ = _simulate("sign", steps=2000)
        assert cvs[-1] < cvs[0]

    def test_anergy_revival_rescues_starved_expert(self):
        """An expert whose load memory collapses gets an IL-2 style bias boost."""
        cfg = irouter.RouterConfig(mode="immune")
        state = irouter.init_router_state(4)
        starved_load = jnp.asarray([0.5, 0.5, 0.0, 0.0])
        for _ in range(100):
            state = irouter.update_router_state(state, starved_load, cfg)
        # starved experts must end with *higher* bias than overloaded ones
        assert float(state.bias[2]) > float(state.bias[0])
        assert float(state.bias[3]) > float(state.bias[1])

    def test_selection_only_bias_does_not_change_gates(self):
        logits = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
        idx0, gates0, _ = irouter.route(logits, jnp.zeros(8), k=2)
        big_bias = jnp.zeros(8).at[3].set(100.0)
        idx1, gates1, _ = irouter.route(logits, big_bias, k=2)
        # expert 3 now always selected...
        assert bool(jnp.all(jnp.any(idx1 == 3, axis=1)))
        # ...but gate values are softmax over *raw* scores of the selected experts
        sel = jnp.take_along_axis(logits, idx1, axis=-1)
        np.testing.assert_allclose(gates1, jax.nn.softmax(sel, -1), rtol=1e-5)


class TestAuxLoss:
    def test_aux_loss_penalizes_correlated_skew(self):
        """f·p correlation is what the Switch loss punishes: skewed assignments
        *with matching router probs* must cost more than uniform ones."""
        e, t = 8, 800
        uniform_idx = jax.random.randint(jax.random.PRNGKey(0), (t, 2), 0, e)
        uniform_probs = jnp.full((t, e), 1.0 / e)
        skewed_idx = jnp.zeros((t, 2), jnp.int32)
        skewed_probs = jnp.full((t, e), 0.01).at[:, 0].set(0.93)
        assert float(irouter.aux_loss(uniform_idx, uniform_probs, e)) \
            < float(irouter.aux_loss(skewed_idx, skewed_probs, e))
