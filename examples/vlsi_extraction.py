"""The paper's experiment end-to-end: extract the NAND netlist with immune-balanced
agents, print the statements (the paper's output format), population dynamics, and
a quick speedup check.

    PYTHONPATH=src python examples/vlsi_extraction.py [--layout dff]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.vlsi import extractor, layout, reference


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--layout", choices=["nand", "dff"], default="nand")
    ap.add_argument("--agents", type=int, default=96)
    args = ap.parse_args()

    lay = layout.nand_layout() if args.layout == "nand" else layout.dff_layout()
    oracle = reference.extract(lay)
    print(f"layout: {args.layout} ({lay.shape[1]}x{lay.shape[2]}), "
          f"{len(oracle.fets)} transistors, {len(oracle.equivs)} node pairs")

    grid, steps, pops = extractor.run_extraction(lay, n_agents=args.agents,
                                                 seed=0, max_steps=8000,
                                                 record=True)
    sim = extractor.harvest(grid, lay)
    ok, msg = extractor.netlists_equivalent(sim, oracle)
    print(f"extracted in {steps} MIMD cycles with {args.agents} agents — "
          f"netlist {'EQUIVALENT to oracle' if ok else 'MISMATCH: ' + msg}")
    print(f"redundant statements deduplicated: {sim.duplicates}\n")

    print("netlist (paper statement format):")
    for i, f in enumerate(sorted(sim.fets, key=str)):
        s, d = sorted(n for _, n in f.sd)
        print(f"  {'PFET' if f.pol == 'p' else 'NFET'} {i}: S {s}, D {d}, "
              f"G {f.g[1]}, L {f.l}, W {f.w}")
    for e in sorted(sim.equivs, key=str):
        a, b = sorted(n for _, n in e.nodes)
        print(f"  Contact: Node {a} == Node {b}")

    print("\npopulation dynamics (paper Fig. 3):")
    marks = [0, 5, 20, 50, 100, 200, min(steps, 7999) - 1]
    print("  step  " + "  ".join(f"{n[:9]:>9s}" for n in extractor.TYPE_NAMES))
    for t in marks:
        print(f"  {t:4d}  " + "  ".join(f"{int(c):9d}" for c in pops[t]))


if __name__ == "__main__":
    main()
