"""Batched serving demo: prefill a batch of prompts, decode with a KV cache, show
per-family decode state (attention KV / SSM state / RG-LRU ring buffers).

    PYTHONPATH=src python examples/serve_batch.py [--arch mamba2-130m]

``--engine`` demos continuous batching instead: staggered requests are admitted
mid-stream into a paged slot pool (prompts land chunk-by-chunk in block-table
pages while other slots keep decoding), finished sequences retire and their
pages return to the free list for reuse. Half the requests share a common
prompt prefix, so with ``--prefix-sharing`` (default) admission adopts the
resident prefix pages with refcount++ instead of re-prefilling them;
``--attn-backend pallas_interpret`` decodes through the Pallas block-table
kernel instead of the XLA gather.

With ``--temperature`` the odd request ids decode through seeded per-slot
sampling lanes (``SamplingParams``) inside the same compiled step while the
even ids stay exact greedy — mixed traffic, one decode dispatch.

    PYTHONPATH=src python examples/serve_batch.py --engine [--arch qwen3-4b] \
        [--temperature 0.8] [--no-prefix-sharing] \
        [--attn-backend pallas_interpret]

``--replicas 2`` (with ``--engine``) routes the same staggered requests
through the multi-replica placement router (``--router immune|rr|jsq``):
immune placement keeps prefix-sharing tenants where their pages live.

``--faults "crash@8:r1 rejoin@24:r1"`` (with ``--replicas > 1``) scripts
replica faults into the run (``serve.faults`` grammar: crash / slow / stall /
pressure / rejoin) — the router's health machine detects the crash, re-places
the stranded requests on survivors bitwise-exactly, and a rejoin swaps in a
cold replica that rewarms from live traffic. ``--fleet-faults`` serves the
fault-laced multi-tenant fleet trace instead of the demo requests, with a
crash+rejoin plan auto-sized to the trace when ``--faults`` is not given:

    PYTHONPATH=src python examples/serve_batch.py --engine --replicas 3 \
        --fleet-faults [--faults "crash@7:r1 rejoin@17:r1"]

A plan containing ``poweroff@tick [restart@tick]`` fail-stops the ENTIRE
fleet mid-trace; the demo then drives through
``serve.durability.run_durable`` — write-ahead journal + warm snapshots in a
scratch dir, a fresh fleet recovered after the loss — and still finishes
every request with bitwise-identical tokens:

    PYTHONPATH=src python examples/serve_batch.py --engine --replicas 2 \
        --fleet-faults --faults "poweroff@12 restart@16"
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import model as model_lib
from repro.serve import decode as serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(configs.ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine demo (staggered arrivals)")
    ap.add_argument("--prefix-sharing", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="refcounted prompt-prefix page sharing in the engine")
    ap.add_argument("--attn-backend", default="xla",
                    choices=("xla", "pallas", "pallas_interpret"),
                    help="paged decode attention backend for the engine")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="engine demo: per-request sampling temperature for "
                         "the odd request ids (0 = all greedy)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine demo: >1 serves through the multi-replica "
                         "placement router (serve.router)")
    ap.add_argument("--router", default="immune",
                    choices=("immune", "rr", "jsq"),
                    help="placement policy when --replicas > 1")
    ap.add_argument("--faults", default=None, metavar="PLAN",
                    help="engine demo with --replicas > 1: scripted replica "
                         "faults, e.g. 'crash@8:r1 rejoin@24:r1' "
                         "(serve.faults plan grammar)")
    ap.add_argument("--fleet-faults", action="store_true",
                    help="with --replicas > 1: serve the fault-laced "
                         "multi-tenant fleet trace (failover_fleet_trace); "
                         "auto-sizes a crash+rejoin plan unless --faults is "
                         "given")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch).smoke()
    params = model_lib.init_params(jax.random.PRNGKey(0), cfg)

    if args.engine:
        _engine_demo(params, cfg, args)
        return
    key = jax.random.PRNGKey(1)
    prompts = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len),
                                            0, cfg.vocab_size)}
    if cfg.family == "vlm":
        prompts["patches"] = jax.random.normal(
            key, (args.batch, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        prompts["frames"] = jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.frontend_dim))

    bias = (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)
    t0 = time.perf_counter()
    toks, cache = serve.generate(params, cfg, prompts,
                                 max_cache=args.prompt_len + args.steps + 8,
                                 steps=args.steps, router_bias=bias)
    dt = time.perf_counter() - t0
    print(f"{args.arch} ({cfg.family}): {args.batch} seqs x {args.steps} tokens "
          f"in {dt:.1f}s (incl. compile)")
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"decode state: {cache_bytes / 2 ** 20:.2f} MiB "
          f"({'O(1)/token recurrent state' if cfg.family in ('ssm', 'hybrid') else 'KV cache'})")
    for i, row in enumerate(toks):
        print(f"  seq {i}: {row.tolist()[:16]}{'...' if args.steps > 16 else ''}")


def _engine_demo(params, cfg, args):
    import numpy as np

    from repro.serve import engine as eng_mod
    from repro.serve import traces
    from repro.serve.api import SamplingParams, ServeRequest

    bias = (jnp.zeros((cfg.num_layers, cfg.num_experts))
            if cfg.num_experts else None)
    # max_cache rounds up to the page grain (16-token pages, chunked prefill)
    ecfg = eng_mod.EngineConfig(
        num_slots=min(args.batch, 4),
        max_cache=-(-(args.prompt_len + args.steps + 16) // 16) * 16,
        prefill_chunk=16,
        prefix_sharing=args.prefix_sharing,
        attn_backend=args.attn_backend)
    rng = np.random.default_rng(0)
    # half the requests ride a common "system prompt" prefix: with sharing on,
    # its pages are prefilled once and adopted (refcount++) by every follower
    # — and the odd rids sample (seeded) while the even ones stay greedy
    prefix = rng.integers(0, cfg.vocab_size,
                          size=args.prompt_len).astype(np.int32)
    reqs = []
    for rid in range(2 * ecfg.num_slots + 2):      # forces slot reuse
        plen = (args.prompt_len // 2, args.prompt_len)[rid % 2]
        toks = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        if rid % 2:
            toks = np.concatenate([prefix, toks[:4]])
        req = ServeRequest(
            rid=rid, tokens=toks,
            params=SamplingParams(
                temperature=args.temperature if rid % 2 else 0.0,
                top_p=0.9 if rid % 2 else 1.0, seed=rid,
                max_new_tokens=(args.steps // 4, args.steps // 2)[rid % 2]),
            rclass=rid % 2, arrival=2 * rid)
        reqs.append(traces.attach_modality_inputs(req, cfg, rng))

    if args.replicas > 1:
        from repro.serve import router as rt_mod
        from repro.serve.faults import FaultInjector, FaultPlan
        spec = args.faults
        if args.fleet_faults:
            reqs, auto_spec = traces.failover_fleet_trace(
                cfg, replicas=args.replicas,
                crash_replica=args.replicas - 1)
            spec = spec or auto_spec
        if spec:
            print(f"fault plan: {spec}")

        def make_router():
            injector = None
            if spec:
                injector = FaultInjector(
                    FaultPlan.parse(spec),
                    engine_factory=lambda: eng_mod.Engine(params, cfg, ecfg,
                                                          router_bias=bias))
            fleet = [eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
                     for _ in range(args.replicas)]
            return rt_mod.Router(fleet,
                                 rt_mod.RouterConfig(policy=args.router),
                                 injector=injector)

        t0 = time.perf_counter()
        if spec and "poweroff" in spec:
            # a full-fleet fail-stop needs the out-of-band recovery driver:
            # journal + warm snapshots in a scratch dir, rebuilt on restart
            import tempfile

            from repro.serve import durability
            scratch = tempfile.mkdtemp(prefix="serve_batch_wal_")
            router, stats = durability.run_durable(
                make_router, reqs, os.path.join(scratch, "journal.wal"),
                snapshot_dir=os.path.join(scratch, "snap"), snapshot_every=4,
                max_ticks=1000)
            print(f"  poweroff survived: {stats['restarts']} restarts, "
                  f"{stats['durability']['recovered_finished']} finished "
                  f"deduped + {stats['durability']['recovered_open']} "
                  f"replayed, {stats['durability']['recovered_pinned_pages']} "
                  f"pinned pages warm (journal+snapshots in {scratch})")
        else:
            router = make_router()
            stats = router.run(reqs, max_ticks=1000)
        dt = time.perf_counter() - t0
        print(f"{args.arch} ({cfg.family}) {args.router} router over "
              f"{args.replicas} replicas: {stats['completed']} requests in "
              f"{stats['ticks']} ticks ({dt:.1f}s incl. compile); placements "
              f"{stats['placements']}, affinity {stats['affinity_hits']}/"
              f"{stats['affinity_checks']} hits, p99 "
              f"{stats['p99_latency']:.0f} ticks")
        if spec:
            print(f"  failover: {stats['deaths']} deaths / {stats['rejoins']}"
                  f" rejoins, {stats['replaced_requests']} re-placed "
                  f"({stats['retries']} retries, {stats['failed']} failed), "
                  f"recovery {stats['recovery_ticks']} ticks, health "
                  f"{stats['health']}")
        for r in router.completed:
            print(f"  req {r.rid}: {r.out_tokens[:12]}"
                  f"{'...' if len(r.out_tokens) > 12 else ''}")
        return
    eng = eng_mod.Engine(params, cfg, ecfg, router_bias=bias)
    t0 = time.perf_counter()
    stats = eng.run(reqs, max_ticks=1000)
    dt = time.perf_counter() - t0
    print(f"{args.arch} ({cfg.family}) continuous batching: "
          f"{stats['completed']} requests over {ecfg.num_slots} slots in "
          f"{stats['ticks']} ticks ({dt:.1f}s incl. compile); "
          f"{stats['mid_stream_admissions']} admitted mid-stream, "
          f"{stats['chunked_prefill_chunks']} prefill chunks, pages high-water "
          f"{stats['pages_hw']}/{stats['pages_budget']} "
          f"[{stats['attn_backend']} decode]")
    print(f"  prefix sharing {'on' if stats['prefix_sharing'] else 'off'}: "
          f"hit rate {stats['prefix_hit_rate']:.2f}, "
          f"{stats['shared_pages_adopted']} pages adopted, "
          f"{stats['cow_forks']} CoW forks, "
          f"{stats['prefill_positions_skipped']} prefill positions skipped")
    for r in sorted(eng.completed, key=lambda r: r.rid):
        mode = "greedy" if r.params.is_greedy \
            else f"T={r.params.temperature} seed={r.params.seed}"
        print(f"  req {r.rid} ({mode}): slot {r.slot}, ticks {r.admit_tick}"
              f"-{r.finish_tick} [{r.finish_reason}]: {r.out_tokens[:12]}"
              f"{'...' if len(r.out_tokens) > 12 else ''}")


if __name__ == "__main__":
    main()
