"""End-to-end driver: train a ~100M-parameter MoE LM (granite-family) with immune
expert balancing for a few hundred steps, with checkpointing and auto-resume.

    PYTHONPATH=src python examples/train_moe_100m.py [--steps 200] [--router aux]

On CPU this is a real (slow) run — use --steps 30 for a smoke pass. Kill it mid-run
and start it again: it resumes from the newest checkpoint.
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs
from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as model_lib
from repro.train.trainer import Trainer
import jax


def moe_100m(router_mode: str) -> ModelConfig:
    """~100M-param MoE: 8 layers, d=512, 8 experts top-2 (granite family)."""
    return dataclasses.replace(
        configs.get_config("granite-moe-3b-a800m"),
        name="moe-100m", num_layers=8, d_model=512, num_heads=8, num_kv_heads=4,
        head_dim=64, d_ff=1024, vocab_size=32_768, num_experts=8,
        experts_per_token=2, capacity_factor=1.25, router_mode=router_mode,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--router", default="immune",
                    choices=["immune", "aux", "sign", "none"])
    ap.add_argument("--workdir", default="/tmp/repro_moe_100m")
    args = ap.parse_args()

    cfg = moe_100m(args.router)
    n = model_lib.param_count(
        jax.eval_shape(lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg)))
    print(f"model: {n / 1e6:.0f}M params "
          f"({model_lib.active_param_count(jax.eval_shape(lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg)), cfg) / 1e6:.0f}M active), "
          f"router={args.router}")

    tcfg = TrainConfig(learning_rate=3e-4, warmup_steps=20,
                       decay_steps=max(args.steps, 100), schedule="wsd")
    tr = Trainer(
        cfg=cfg, tcfg=tcfg, workdir=args.workdir, batch=8, seq=128,
        ckpt_every=50, log_every=10,
        on_metrics=lambda m: print(
            f"step {m['step']:4d}  loss {m['loss']:.3f}  "
            f"load_cv {m['load_cv']:.3f}  drop {100 * m['drop_frac']:.2f}%  "
            f"{m['sec_per_step']:.2f}s/step"))
    tr.train(args.steps)
    print(f"done; checkpoints in {args.workdir}")


if __name__ == "__main__":
    main()
