"""Quickstart: train a tiny dense LM on the synthetic corpus, then serve it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro import configs
from repro.configs.base import TrainConfig
from repro.serve import decode as serve
from repro.train.trainer import Trainer


def main():
    cfg = configs.get_config("smollm-360m").smoke()
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=10, decay_steps=2000)
    with tempfile.TemporaryDirectory() as workdir:
        tr = Trainer(cfg=cfg, tcfg=tcfg, workdir=workdir, batch=8, seq=64,
                     log_every=10,
                     on_metrics=lambda m: print(
                         f"step {m['step']:4d}  loss {m['loss']:.3f}  "
                         f"lr {m['lr']:.2e}  {m['sec_per_step']:.2f}s/step"))
        state = tr.train(100)

    print("\nserving a 3-prompt batch, 16 greedy tokens each:")
    prompts = {"tokens": jax.random.randint(jax.random.PRNGKey(0), (3, 8), 0,
                                            cfg.vocab_size)}
    toks, _ = serve.generate(state.params, cfg, prompts, max_cache=64, steps=16)
    for i, row in enumerate(toks):
        print(f"  prompt {i}: {row.tolist()}")


if __name__ == "__main__":
    main()
