"""Straggler mitigation: immune scheduler vs static assignment on simulated fleets.

Scenarios: persistent straggler, transient hiccups (should NOT trigger rebalancing
— the regulation delay), node death + recovery (anergy + revival). Metric: total
simulated step time (sum over steps of max-over-workers).
"""
from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from repro.core import scheduler as sch


def _scenarios(t=400, w=16, seed=0):
    rng = np.random.default_rng(seed)
    base = 1.0 + 0.05 * rng.standard_normal((t, w))

    persistent = base.copy()
    persistent[:, 0] *= 0.3

    hiccup = base.copy()
    for s in range(20, t, 60):                 # 5-step transient stalls
        hiccup[s:s + 5, rng.integers(w)] *= 0.2

    death = base.copy()
    death[t // 4: 3 * t // 4, :2] = 0.0        # two nodes die, then recover

    return {"persistent_straggler": persistent, "transient_hiccups": hiccup,
            "death_and_recovery": death}


def run(out: str = "benchmarks/results/scheduler_bench.csv"):
    rows = []
    for name, trace in _scenarios().items():
        trace = jnp.asarray(np.clip(trace, 1e-3, None), jnp.float32)
        t_imm = float(jnp.sum(sch.simulate(trace)))
        t_static = float(jnp.sum(sch.simulate(trace, static=True)))
        rows.append((name, t_imm, t_static, t_static / t_imm))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("scenario,immune_time,static_time,speedup\n")
        for r in rows:
            f.write(f"{r[0]},{r[1]:.2f},{r[2]:.2f},{r[3]:.3f}\n")
    return rows


def main():
    rows = run()
    for name, ti, ts, sp in rows:
        print(f"  {name:24s} immune={ti:8.2f}  static={ts:8.2f}  "
              f"speedup={sp:5.2f}x")


if __name__ == "__main__":
    main()
