"""Heuristic ablations: how much each immune mechanism contributes.

Variants:
  full             — everything on (the paper's configuration)
  no_damping       — ancestor-transition damping off (limit cycles allowed)
  no_suppression   — multi-stage delayed suppression of layer finders off
  no_exploration   — epsilon-random walk off (greedy-only movement)

Metric: completion steps on the NAND layout (mean over seeds; max_steps on
non-termination — the honest cost of a heuristic's absence).
"""
from __future__ import annotations

import argparse
import os

import jax
import numpy as np

from repro.core import agent_model
from repro.core.vlsi import extractor, layout

VARIANTS = {
    "full": {},
    "no_damping": {"ancestor_damp": 1.0},
    "no_suppression": {"finder_suppression": False},
    "no_exploration": {"walk_eps": 0.0},
}


def _run(lay, n_agents, seed, max_steps, **knobs):
    grid = extractor.make_grid(lay)
    model = extractor.make_extractor(n_agents, (grid.shape[1], grid.shape[2]),
                                     **knobs)
    key = jax.random.PRNGKey(seed)
    ka, kr = jax.random.split(key)
    agents = agent_model.uniform_random_agents(
        ka, n_agents, grid.shape[1], grid.shape[2], extractor.STATE_SIZE,
        init_type=extractor.FINDER)
    _, _, steps = model.run_while(grid, agents, kr, max_steps, extractor.done_fn)
    return int(steps)


def run(n_agents: int = 96, seeds=(0, 1, 2), max_steps: int = 8000,
        out: str = "benchmarks/results/ablations.csv"):
    lay = layout.nand_layout()
    rows = []
    for name, knobs in VARIANTS.items():
        steps = [_run(lay, n_agents, s, max_steps, **knobs) for s in seeds]
        rows.append((name, float(np.mean(steps)), max(steps),
                     sum(s >= max_steps for s in steps)))
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("variant,mean_steps,max_steps,timeouts\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=96)
    args = ap.parse_args()
    rows = run(n_agents=args.agents)
    base = rows[0][1]
    for name, mean, worst, timeouts in rows:
        print(f"  {name:16s} mean={mean:7.1f} steps  worst={worst}  "
              f"timeouts={timeouts}  ({mean / base:+.2f}x of full)")


if __name__ == "__main__":
    main()
