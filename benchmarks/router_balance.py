"""MoE balancing: immune regulation vs aux-loss vs sign-bias vs none.

Drives each balancing mode against a persistently skewed router (the adversarial
case for load balancing) and a *drifting* skew (tests response speed — the paper's
immunological-memory argument). Metrics: tail load CV, token drop fraction at
capacity factor 1.25, and recovery steps after a drift event.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import router as irouter

MODES = ("immune", "sign", "aux", "none")


def _loads(idx, e):
    return irouter.load_fractions(idx, e)


def _drop_frac(idx, e, k, cf=1.25):
    t = idx.shape[0]
    cap = int(cf * t * k / e)
    counts = np.bincount(np.asarray(idx).reshape(-1), minlength=e)
    return float(np.maximum(counts - cap, 0).sum() / (t * k))


def run(e: int = 16, t: int = 1024, k: int = 2, steps: int = 600,
        drift_at: int = 300, seed: int = 0,
        out: str = "benchmarks/results/router_balance.csv"):
    key = jax.random.PRNGKey(seed)
    skew_a = jnp.linspace(2.0, 0.0, e)[None, :]
    skew_b = jnp.linspace(0.0, 2.0, e)[None, :]      # drift: preference flips
    results = {}
    for mode in MODES:
        cfg = irouter.RouterConfig(mode=mode)
        state = irouter.init_router_state(e)
        cvs, drops = [], []
        for i in range(steps):
            skew = skew_a if i < drift_at else skew_b
            logits = skew + 0.5 * jax.random.normal(jax.random.fold_in(key, i),
                                                    (t, e))
            # 'aux' trains the router against the loss; emulate its long-run
            # effect with a gradient step on the bias proxy (structural stand-in)
            idx, gates, probs = irouter.route(logits, state.bias, k)
            load = _loads(idx, e)
            if mode == "aux":
                # one SGD step on E*sum(f*p) wrt a bias added to logits
                grad = e * (jnp.mean(probs, 0) * 1.0)      # d(aux)/d(bias) ~ f-term
                new_bias = jnp.clip(state.bias - 0.3 * (load - 1.0 / e) * e,
                                    -4, 4)
                state = state._replace(bias=new_bias - new_bias.mean())
            else:
                state = irouter.update_router_state(state, load, cfg)
            cvs.append(float(irouter.load_cv(load)))
            drops.append(_drop_frac(idx, e, k))
        cvs = np.asarray(cvs)
        # recovery: steps after the drift until CV back under 1.5x pre-drift tail
        pre = cvs[drift_at - 50:drift_at].mean()
        rec = next((i for i in range(drift_at, steps)
                    if cvs[i] < max(1.5 * pre, 0.15)), steps) - drift_at
        results[mode] = {
            "tail_cv": float(cvs[-50:].mean()),
            "tail_drop": float(np.mean(drops[-50:])),
            "recovery_steps": rec,
            "trace": cvs,
        }

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("step," + ",".join(f"cv_{m}" for m in MODES) + "\n")
        for i in range(steps):
            f.write(f"{i}," + ",".join(f"{results[m]['trace'][i]:.4f}"
                                       for m in MODES) + "\n")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--experts", type=int, default=16)
    args = ap.parse_args()
    res = run(e=args.experts)
    print(f"{'mode':8s} {'tail load CV':>12s} {'tail drop%':>10s} "
          f"{'recovery steps':>14s}")
    for m in MODES:
        r = res[m]
        print(f"{m:8s} {r['tail_cv']:12.3f} {100 * r['tail_drop']:10.2f} "
              f"{r['recovery_steps']:14d}")


if __name__ == "__main__":
    main()
