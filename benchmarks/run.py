"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus the summary lines of each
sub-benchmark). Heavier variants live in the individual modules:

    python -m benchmarks.fig3_population       # paper Fig. 3
    python -m benchmarks.fig4_speedup          # paper Fig. 4
    python -m benchmarks.ablations             # heuristic ablations
    python -m benchmarks.router_balance        # MoE balance: immune vs baselines
    python -m benchmarks.scheduler_bench       # straggler mitigation
    python -m benchmarks.serve_engine          # serving admission: immune vs FIFO
    python -m benchmarks.kernel_bench          # Pallas kernel microbenches
    python -m benchmarks.roofline_report       # dry-run roofline tables
"""
from __future__ import annotations

import time

import numpy as np


def _timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def main() -> None:
    rows = []

    from benchmarks import fig4_speedup
    res, us = _timed(fig4_speedup.run, agent_counts=(64, 128, 256), seeds=(0, 1))
    rows.append(("fig4_speedup_exponent", us,
                 f"saturated_slope={res['slope_saturated']:+.3f};paper=-0.30"))

    from benchmarks import fig3_population
    res, us = _timed(fig3_population.run, n_agents=175)
    ok = all(res["checks"].values())
    rows.append(("fig3_population_dynamics", us,
                 f"steps={res['steps']};checks={'PASS' if ok else 'FAIL'}"))

    from benchmarks import ablations
    res, us = _timed(ablations.run, n_agents=96, seeds=(0, 1))
    base = res[0][1]
    worst = max(r[1] for r in res)
    rows.append(("heuristic_ablations", us,
                 f"full={base:.0f}steps;worst_ablation={worst / base:.2f}x"))

    from benchmarks import router_balance
    res, us = _timed(router_balance.run, steps=400, drift_at=200)
    rows.append(("moe_balance_immune", us,
                 f"tail_cv={res['immune']['tail_cv']:.3f};"
                 f"none={res['none']['tail_cv']:.3f}"))

    from benchmarks import scheduler_bench
    res, us = _timed(scheduler_bench.run)
    sp = np.mean([r[3] for r in res])
    rows.append(("straggler_scheduler", us, f"mean_speedup_vs_static={sp:.2f}x"))

    from benchmarks import serve_engine
    res, us = _timed(serve_engine.run, num_requests=24, seeds=(0,))
    s = res["summary"]
    rows.append(("serve_engine_paged_kv", us,
                 f"paged_p99={s['paged_immune_p99']:.0f};"
                 f"fixed_p99={s['fixed_immune_p99']:.0f};"
                 f"concurrency={s['paged_concurrency_hw']:.0f}v"
                 f"{s['fixed_concurrency_hw']:.0f};"
                 f"checks={'PASS' if all(s['checks'].values()) else 'FAIL'}"))

    from benchmarks import kernel_bench
    kres, us = _timed(kernel_bench.run)
    for name, kus, derived in kres:
        rows.append((name, kus, derived))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
