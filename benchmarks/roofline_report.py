"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json


def load(path="benchmarks/results/dryrun.jsonl"):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        # last record per cell+pcfg wins (later sweeps overwrite baselines)
        key = (r["arch"], r["shape"], r["mesh"],
               json.dumps(r.get("pcfg", {}), sort_keys=True))
        recs[key] = r
    return list(recs.values())


def table(recs, mesh="16x16"):
    rows = []
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"{r['dominant']} | {r['roofline_frac']:.2f} | "
            f"{'yes' if r['fits_hbm'] else 'NO'} |")
    head = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
            "dominant | roofline | fits HBM |\n|---|---|---|---|---|---|---|---|")
    return head + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="benchmarks/results/dryrun.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    print(table(load(args.path), args.mesh))


if __name__ == "__main__":
    main()
