"""Paper Fig. 3: agent population dynamics on the DFF-scale layout (175 agents).

Emits a CSV trace (step, count-per-type) and checks the qualitative shape the paper
reports: layer-finder crash, node-labeller spike, fet-output/contact-finder waves,
all-propagator steady state.
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core.vlsi import extractor, layout, reference


def run(n_agents: int = 175, seed: int = 0, max_steps: int = 6000,
        out: str = "benchmarks/results/fig3_population.csv"):
    lay = layout.dff_layout()
    grid, steps, pops = extractor.run_extraction(lay, n_agents=n_agents, seed=seed,
                                                 max_steps=max_steps, record=True)
    sim = extractor.harvest(grid, lay)
    ok, msg = extractor.netlists_equivalent(sim, reference.extract(lay))

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("step," + ",".join(extractor.TYPE_NAMES) + "\n")
        for t in range(min(steps + 50, max_steps)):
            f.write(f"{t}," + ",".join(str(int(c)) for c in pops[t]) + "\n")

    pops = np.asarray(pops)
    late = min(steps, max_steps - 1)
    checks = {
        "extraction_correct": ok,
        "terminated": steps < max_steps,
        "finder_crash": bool(pops[late, extractor.FINDER]
                             < pops[:30, extractor.FINDER].max() / 4),
        "labeller_spike": bool(pops[:60, extractor.LABELLER].max()
                               >= pops[0, extractor.LABELLER]),
        "propagator_steady_state": bool(pops[late, extractor.PROPAGATOR]
                                        == n_agents),
    }
    return {"steps": steps, "checks": checks, "csv": out,
            "duplicates": sim.duplicates}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=175)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    res = run(args.agents, args.seed)
    print(f"fig3: terminated at {res['steps']} steps; redundant records: "
          f"{res['duplicates']}")
    for k, v in res["checks"].items():
        print(f"  {k}: {'PASS' if v else 'FAIL'}")
    print(f"  trace -> {res['csv']}")


if __name__ == "__main__":
    main()
