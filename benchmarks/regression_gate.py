"""Bench-regression gate: diff a fresh ``BENCH_serve.json`` against the
committed baseline and fail CI on a regression.

Two kinds of rules, deliberately asymmetric:

  * **parity bits are exact**: every ``*_parity_exact`` flag anywhere in the
    new results must be ``True``. Token parity (engine vs one-shot oracle —
    shared/CoW pages, seeded sampling, pinned-adopt, preempted-then-resumed)
    is a correctness invariant, not a metric; there is no tolerance and no
    baseline comparison, a single False fails the gate.
  * **capacity metrics must not regress** vs the committed baseline:
    admission depth under contention (``preemption.summary.
    preempt_concurrency_hw``), the pinned prefix cache's hit rate
    (``pinning.summary.pinned_hit_rate``), the placement router's
    prefix-affinity hit rate (``routing.summary.affinity_hit_rate``), immune
    goodput under crash-of-one failover
    (``failover.summary.immune_goodput``), goodput across a full-fleet
    power loss (``durability.summary.poweroff_goodput``), and the
    speculative-decoding draft accept rate
    (``spec_decode.summary.spec_accept_rate``) must each be at
    least the baseline's value minus a small epsilon.
    Improvements pass silently; update the baseline when they should become
    the new floor.

The ``routing`` section's own checks carry the multi-replica acceptance bar:
immune-placement p99 at most the best baseline policy's (rr/jsq) and
placement invariance bitwise exact across policies and replica counts.

All engine ``checks`` dicts in the new results must also be green — those are
each section's own acceptance bars (admits-deeper, p99-no-worse, 0.3x prefill
ratio, ...), evaluated against the new run alone.

Baselines live in ``benchmarks/baselines/`` (one per benchmark profile; CI
runs ``--smoke`` so it diffs against ``BENCH_serve_smoke.json``). A baseline
missing a section skips that comparison with a note — that is what allows the
PR introducing a new section to also introduce its baseline.

    PYTHONPATH=src python -m benchmarks.regression_gate \
        --new BENCH_serve.json \
        --baseline benchmarks/baselines/BENCH_serve_smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys

# capacity metrics gated against the baseline: (json path, epsilon)
NO_REGRESS = (
    (("preemption", "summary", "preempt_concurrency_hw"), 0.0),
    (("pinning", "summary", "pinned_hit_rate"), 0.01),
    (("routing", "summary", "affinity_hit_rate"), 0.01),
    (("failover", "summary", "immune_goodput"), 0.01),
    (("durability", "summary", "poweroff_goodput"), 0.01),
    (("spec_decode", "summary", "spec_accept_rate"), 0.01),
)


def _walk_parity(node, path=""):
    """Yield (path, value) for every *_parity_exact flag in the tree."""
    if isinstance(node, dict):
        for k, v in node.items():
            p = f"{path}.{k}" if path else str(k)
            if str(k).endswith("parity_exact"):
                yield p, v
            else:
                yield from _walk_parity(v, p)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk_parity(v, f"{path}[{i}]")


def _walk_checks(node, path=""):
    """Yield (path, checks dict) for every summary-level checks dict."""
    if isinstance(node, dict):
        for k, v in node.items():
            p = f"{path}.{k}" if path else str(k)
            if k == "checks" and isinstance(v, dict):
                yield p, v
            else:
                yield from _walk_checks(v, p)


def _lookup(tree, path):
    node = tree
    for k in path:
        if not isinstance(node, dict) or k not in node:
            return None
        node = node[k]
    return node


def gate(new: dict, baseline: dict) -> list:
    """Return the list of failure messages (empty == gate passes)."""
    failures = []
    for path, val in _walk_parity(new):
        if val is not True:
            failures.append(f"parity bit {path} is {val!r} — token parity "
                            f"broke (no tolerance)")
    for path, checks in _walk_checks(new):
        for name, ok in checks.items():
            if ok is not True:
                failures.append(f"check {path}.{name} failed in the new run")
    for path, eps in NO_REGRESS:
        new_v, base_v = _lookup(new, path), _lookup(baseline, path)
        dotted = ".".join(path)
        if new_v is None:
            failures.append(f"metric {dotted} missing from the new results")
            continue
        if base_v is None:
            print(f"note: baseline has no {dotted} — comparison skipped "
                  f"(new value {new_v:.3f}); commit an updated baseline")
            continue
        if new_v < base_v - eps:
            failures.append(f"metric {dotted} regressed: {new_v:.3f} < "
                            f"baseline {base_v:.3f} (eps {eps})")
        else:
            print(f"ok: {dotted} {new_v:.3f} >= baseline {base_v:.3f} - {eps}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", default="BENCH_serve.json",
                    help="freshly generated benchmark results")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_serve_smoke.json",
                    help="committed baseline to diff against")
    args = ap.parse_args()

    with open(args.new) as fh:
        new = json.load(fh)
    with open(args.baseline) as fh:
        baseline = json.load(fh)

    failures = gate(new, baseline)
    if failures:
        print("BENCH REGRESSION:")
        for f in failures:
            print(f"  - {f}")
        sys.exit(1)
    n_parity = sum(1 for _ in _walk_parity(new))
    n_checks = sum(len(c) for _, c in _walk_checks(new))
    print(f"bench gate OK: {n_parity} parity bits exact, {n_checks} checks "
          f"green, no capacity regression vs {args.baseline}")


if __name__ == "__main__":
    main()
