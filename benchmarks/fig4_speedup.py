"""Paper Fig. 4: completion time vs number of agents on the NAND layout.

The paper reports a log-log slope of ~ -0.30 for its NAND workload. We sweep agent
counts with several seeds, validate every run's netlist against the oracle, emit a
CSV (n_agents, mean/min/max steps — the paper's three curves), and fit the slope.

Two fits are reported: the full range, and the saturated regime (n >= 64) where the
serial fraction dominates — the paper's regime (it plots up to high agent counts
where the curve flattens; our absolute counts differ because our grid is smaller).
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.core.vlsi import extractor, layout, reference


def run(agent_counts=(16, 32, 64, 96, 128, 192, 256), seeds=(0, 1, 2),
        max_steps: int = 8000, out: str = "benchmarks/results/fig4_speedup.csv"):
    lay = layout.nand_layout()
    oracle = reference.extract(lay)
    rows = []
    for n in agent_counts:
        steps_list = []
        for seed in seeds:
            grid, steps, _ = extractor.run_extraction(lay, n_agents=n, seed=seed,
                                                      max_steps=max_steps)
            sim = extractor.harvest(grid, lay)
            ok, msg = extractor.netlists_equivalent(sim, oracle)
            assert ok, f"n={n} seed={seed}: {msg}"
            steps_list.append(steps)
        rows.append((n, float(np.mean(steps_list)), min(steps_list),
                     max(steps_list)))

    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write("n_agents,mean_steps,min_steps,max_steps\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")

    ns = np.asarray([r[0] for r in rows], float)
    means = np.asarray([r[1] for r in rows], float)
    slope_full = float(np.polyfit(np.log(ns), np.log(means), 1)[0])
    sat = ns >= 64
    slope_sat = float(np.polyfit(np.log(ns[sat]), np.log(means[sat]), 1)[0])
    return {"rows": rows, "slope_full": slope_full, "slope_saturated": slope_sat,
            "csv": out}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer points/seeds for CI")
    args = ap.parse_args()
    if args.quick:
        res = run(agent_counts=(64, 128, 256), seeds=(0, 1))
    else:
        res = run()
    for n, mean, lo, hi in res["rows"]:
        print(f"  n={n:4d}  steps mean={mean:7.1f}  min={lo}  max={hi}")
    print(f"fig4: speedup exponent (full fit)      = {res['slope_full']:+.3f}")
    print(f"fig4: speedup exponent (saturated fit) = {res['slope_saturated']:+.3f}"
          f"   (paper: -0.30 on its NAND workload)")
    print(f"  curve -> {res['csv']}")


if __name__ == "__main__":
    main()
